"""Property-based tests (hypothesis) on compiler invariants.

For arbitrary randomly-wired layer graphs:
  1. codo_opt leaves no coarse violations;
  2. every FIFO-classified edge is fine-violation-free;
  3. the lowered program is numerically equal to the un-optimized oracle;
  4. schedule degrees are legal (≤ trip, never on unsafe loops);
  5. the final latency never exceeds the sequential baseline.
"""

import jax.numpy as jnp
import numpy as np
import pytest

# Declared in requirements-dev.txt / the `dev` extra; local runs without it
# skip instead of erroring at collection.
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (codo_opt, coarse_violations, fine_violations, lower,
                        verify_violation_free)
from repro.core.reuse import parallel_safety
from repro.models.dataflow_models import GB


def build_random_graph(layer_plan, skips, width):
    """An MLP-ish chain with optional residual skips (SPMC generators)."""
    b = GB("rand")
    x = b.load(b.input("x", (4, width)))
    outs = [x]
    for i, kind in enumerate(layer_plan):
        if kind == 0:
            h = b.fc(outs[-1], width, relu=True)
        elif kind == 1:
            h = b.fc(outs[-1], width)
        else:
            h = b.gelu(outs[-1])
        if i in skips and b.shape[outs[-1]] == b.shape[h]:
            h = b.add(h, outs[-1])
        outs.append(h)
    b.mark_output(outs[-1])
    return b.g


graph_strategy = st.tuples(
    st.lists(st.integers(0, 2), min_size=1, max_size=6),
    st.sets(st.integers(0, 5), max_size=3),
    st.sampled_from([8, 16, 32]),
)


@settings(max_examples=25, deadline=None)
@given(graph_strategy)
def test_compiler_invariants(plan):
    layer_plan, skips, width = plan
    g = build_random_graph(layer_plan, skips, width)
    g.validate()
    compiled = codo_opt(g)

    # 1 & 2: violation-free design
    assert not coarse_violations(compiled.graph)
    assert not verify_violation_free(compiled)

    # 3: functional equivalence vs the oracle
    rng = np.random.default_rng(0)
    env = {buf.name: jnp.asarray(rng.standard_normal(buf.shape) * 0.1,
                                 jnp.float32)
           for buf in g.buffers.values() if buf.kind in ("input", "weight")}
    got = lower(compiled, jit=False)(env)
    want = g.execute(env)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=2e-4, atol=2e-4)

    # 4: legal degrees
    for t in compiled.graph.tasks:
        for l in t.loops:
            assert 1 <= l.parallel <= max(l.trip, 1)
            if l.parallel > 1:
                assert parallel_safety(t, l.var) != "unsafe"

    # 5: never slower than sequential
    assert compiled.final.total_cycles <= compiled.baseline.total_cycles * 1.01


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(1, 4))
def test_fifo_fraction_bounds(n_layers, seed):
    g = build_random_graph([0] * n_layers, set(), 16)
    c = codo_opt(g)
    assert 0.0 <= c.fifo_fraction <= 1.0
    # pure fc/relu chains are fully streamable after rewriting
    assert c.fifo_fraction == 1.0
