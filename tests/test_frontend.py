"""Traced-function frontend (repro.core.frontend + repro.api / codo).

Covers the ISSUE-4 acceptance criteria: structural-hash parity between
traced and hand-built graphs (same compile-cache key), tracer edge cases
(multi-consumer bypass, multi-producer init/pad pairs, stencil re-reads),
numeric end-to-end equality ``codo.compile(fn)(x) == fn(x)`` for every
traced Table II kernel, pass-budget enforcement, npz input loading for
artifact serving, and process-pool composition of traced workloads.
"""

import pickle

import numpy as np
import pytest

import codo
from repro.core import (CodoOptions, CompileCache, PassBudgetError,
                        codo_opt, codo_opt_batch, enforce_pass_budgets,
                        kernel_workloads, verify_violation_free)
from repro.core import frontend as F
from repro.core.compiler import BatchJob
from repro.core.patterns import (MPSC, SPMC, STENCIL_REREAD,
                                 coarse_violations, fine_violations)
from repro.models import dataflow_models as dm

# Table II traced functions at test-scale shapes (structure identical to
# the paper-scale defaults; only trip counts shrink).
SMALL_KERNELS = {
    "atax": (dm.atax_fn, [(48, 40), (40,)]),
    "gesummv": (dm.gesummv_fn, [(40, 40), (40, 40), (40,)]),
    "gemm": (dm.gemm_fn, [(24, 16), (16, 20)]),
    "mvt": (dm.mvt_fn, [(40, 40), (40,), (40,)]),
    "3mm": (dm.three_mm_fn, [(16, 16)] * 4),
    "residual_mlp": (dm.residual_mlp_fn, [(8, 32)]),
    "autoencoder": (dm.autoencoder_fn, [(8, 64)]),
    "residual_block": (dm.residual_block_fn, [(1, 8, 12, 12)]),
    "dws_conv_block": (dm.dws_conv_block_fn, [(1, 8, 12, 12)]),
    "conv3_block": (dm.conv3_block_fn, [(1, 3, 14, 14)]),
    "feed_forward": (dm.feed_forward_fn, [(16, 32)]),
    "multi_head_attention": (dm.multi_head_attention_fn, [(24, 32)]),
}


def _inputs(shapes, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(s).astype(np.float32) for s in shapes]


# --------------------------------------------------------------------------
# Structural parity: tracing is a frontend, not a different compiler input
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(dm.HANDBUILT_BENCHES))
def test_traced_equals_handbuilt(name):
    traced_builder, hand_builder = dm.HANDBUILT_BENCHES[name]
    traced, hand = traced_builder(), hand_builder()
    assert traced.structural_signature() == hand.structural_signature()
    assert traced.structural_hash() == hand.structural_hash()


def test_traced_hits_handbuilt_cache_entry():
    """Same structural hash => same compile-cache key: a graph compiled
    through the low-level road is a warm hit for codo.compile."""
    cache = CompileCache()
    cold = codo_opt(dm.gemm_handbuilt(24, 20, 16), cache=cache)
    assert not cold.cache_hit
    warm = codo.compile(dm.gemm_fn, (24, 16), (16, 20), name="gemm",
                        cache=cache)
    assert warm.cache_hit
    assert warm.graph.structural_hash() == cold.graph.structural_hash()


# --------------------------------------------------------------------------
# Tracer mechanics + edge cases
# --------------------------------------------------------------------------


def test_trace_io_names_follow_parameters():
    g, ins, outs = F.trace_io(dm.mvt_fn, (8, 8), (8,), (8,), name="mvt")
    assert ins == ["A", "y1", "y2"]
    assert len(outs) == 1 and g.buffers[outs[0]].kind == "output"
    assert [b.name for b in g.inputs()] == ins


def test_operator_sugar_matches_explicit_ops():
    def sugar(x, w):
        return (x @ w + x).T * 2.0

    def explicit(x, w):
        return F.scale(F.transpose(F.add(F.matmul(x, w), x)), 2.0)

    a = F.trace(sugar, (6, 6), (6, 6), name="g")
    b = F.trace(explicit, (6, 6), (6, 6), name="g")
    assert a.structural_hash() == b.structural_hash()


def test_multi_consumer_bypass():
    """Fig. 4a: a residual skip makes the loaded input SPMC."""
    g = F.trace(dm.residual_mlp_fn, (4, 16))
    vs = coarse_violations(g)
    assert SPMC in {v.kind for v in vs}
    ld = next(b for b in g.buffers.values() if b.name.startswith("ld"))
    assert len(g.consumers(ld.name)) == 2


def _pad_pair_conv(x):
    p = F.pad(x, 1, pair=True)
    return F.conv(p, 4, 3, pad=0, relu=False)


def test_multi_producer_init_pad_pair():
    """Fig. 4b: pad(pair=True) emits init+fill producers of one buffer;
    the coarse pass fuses them and the fused design stays numerically
    equal to the eager function."""
    g = F.trace(_pad_pair_conv, (1, 3, 8, 8))
    pad_buf = next(b.name for b in g.buffers.values()
                   if b.name.startswith("pad"))
    assert len(g.producers(pad_buf)) == 2
    assert MPSC in {v.kind for v in coarse_violations(g)}

    (x,) = _inputs([(1, 3, 8, 8)])
    want = g.execute({"x": x, **{b.name: F.weight_init(b.shape)
                                 for b in g.weights()}})
    program = codo.compile(_pad_pair_conv, (1, 3, 8, 8), cache=None)
    assert not coarse_violations(program.graph)
    assert not verify_violation_free(program.compiled)
    got = program(x, jit=False)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(list(want.values())[0]),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(_pad_pair_conv(x)),
                               rtol=1e-5, atol=1e-5)


def test_stencil_reread_from_conv_window():
    """A conv window re-reads padded rows: the traced graph must carry the
    stride-bearing access the fine pass classifies as a stencil re-read."""
    g = F.trace(dm.conv3_block_fn, (1, 3, 10, 10))
    kinds = {v.kind for v in fine_violations(g)}
    assert STENCIL_REREAD in kinds
    conv = next(t for t in g.tasks if t.op == "conv")
    window = [a for a in conv.reads
              if any(len(dim) > 1 for dim in a.index)]
    assert window, "conv input read lost its multi-var window dims"


def test_trace_errors():
    with pytest.raises(F.TraceError):       # returns an input unchanged
        F.trace(lambda x: x, (4,))
    with pytest.raises(F.TraceError):       # lifted array has the wrong shape
        F.trace(lambda x: F.add(x, np.ones((5,), np.float32)), (4,))
    with pytest.raises(F.TraceError):       # object arrays cannot lift
        F.trace(lambda x: F.add(x, np.array([object()] * 4)), (4,))
    with pytest.raises(F.TraceError):       # same buffer returned twice
        F.trace(lambda x: (F.relu(x),) * 2, (4,))

    def mixed(a):
        leaked = {}

        def inner(b):
            leaked["b"] = F.relu(b)
            return leaked["b"]

        F.trace(inner, (4,))                # buffers must not cross traces
        return F.add(a, leaked["b"])

    with pytest.raises(F.TraceError):
        F.trace(mixed, (4,))


def test_trace_requires_specs_and_callable():
    with pytest.raises(F.TraceError):
        F.trace(dm.gemm_fn)
    with pytest.raises(F.TraceError):
        F.trace("not callable", (4,))
    with pytest.raises(F.TraceError):
        F.trace(lambda x: F.relu(x), 7)     # int is not a shape


# --------------------------------------------------------------------------
# Numeric end-to-end: codo.compile(fn)(x) == fn(x) for every Table II kernel
# --------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(SMALL_KERNELS))
def test_compiled_matches_eager(name):
    fn, shapes = SMALL_KERNELS[name]
    xs = _inputs(shapes)
    program = codo.compile(fn, *shapes, cache=None)
    got = program(*xs, jit=False)
    want = fn(*xs)                       # the same function, run eagerly
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    program.verify(*xs)                  # and against the task-level oracle


def test_compiled_jit_path():
    fn, shapes = SMALL_KERNELS["residual_mlp"]
    xs = _inputs(shapes)
    program = codo.compile(fn, *shapes, cache=None)
    np.testing.assert_allclose(np.asarray(program(*xs, jit=True)),
                               np.asarray(fn(*xs)), rtol=1e-4, atol=1e-5)


def test_bound_weights_override_defaults():
    fn, shapes = SMALL_KERNELS["feed_forward"]
    xs = _inputs(shapes)
    program = codo.compile(fn, *shapes, cache=None)
    wnames = [b.name for b in program.graph.weights()]
    custom = {n: np.zeros(program.graph.buffers[n].shape, np.float32)
              for n in wnames}
    program.bind(**custom)
    out = program(*xs, jit=False)
    assert np.allclose(np.asarray(out), 0.0)     # all-zero weights
    with pytest.raises(KeyError):
        program.bind(nonexistent=np.zeros((1,)))
    with pytest.raises(ValueError):
        program.bind(**{wnames[0]: np.zeros((3, 3), np.float32)})


def test_call_signature_validation():
    program = codo.compile(dm.gemm_fn, (8, 6), (6, 4), cache=None)
    with pytest.raises(TypeError):
        program(np.zeros((8, 6), np.float32))            # missing B
    with pytest.raises(TypeError):
        program(*_inputs([(8, 6), (6, 4), (4, 4)]))      # too many
    with pytest.raises(ValueError):
        program(np.zeros((9, 6), np.float32), np.zeros((6, 4), np.float32))
    inter = next(b for b in program.graph.buffers.values()
                 if b.kind not in ("input", "weight"))
    with pytest.raises(KeyError):                        # not overridable
        program.make_env(*_inputs([(8, 6), (6, 4)]),
                         **{inter.name: np.zeros(inter.shape, np.float32)})


def test_export_load_roundtrip(tmp_path):
    fn, shapes = SMALL_KERNELS["gemm"]
    xs = _inputs(shapes)
    program = codo.compile(fn, *shapes, cache=None)
    path = tmp_path / "gemm.json"
    program.export(str(path))
    loaded = codo.load(str(path))
    assert loaded.graph.structural_hash() == program.graph.structural_hash()
    np.testing.assert_allclose(np.asarray(loaded(*xs, jit=False)),
                               np.asarray(program(*xs, jit=False)),
                               rtol=1e-5, atol=1e-5)


def test_compile_accepts_ready_graph():
    g = dm.gemm_handbuilt(12, 10, 8)
    program = codo.compile(g, cache=None)
    assert program.input_names == ["A", "B"]
    with pytest.raises(codo.TraceError):
        codo.compile(g, (12, 8), cache=None)


# --------------------------------------------------------------------------
# Reflected operators (satellite: __rsub__/__rtruediv__/__rmatmul__ & co.)
# Each traced expression must equal the same function run eagerly —
# bit-exactly, since scalar forms lower to true-division/affine ops.
# --------------------------------------------------------------------------

_W44 = (np.arange(16, dtype=np.float32).reshape(4, 4) / 10.0)
_C34 = np.linspace(-1.0, 1.0, 12, dtype=np.float32).reshape(3, 4)

REFLECTED_CASES = {
    "rsub_scalar": ((3, 4), lambda x: 2.0 - x),
    "sub_scalar": ((3, 4), lambda x: x - 2.5),
    "rtruediv_scalar": ((3, 4), lambda x: 3.0 / (F.relu(x) + 4.0)),
    "truediv_scalar": ((3, 4), lambda x: x / 3.0),
    "radd_scalar": ((3, 4), lambda x: 1.5 + x),
    "rmul_scalar": ((3, 4), lambda x: 2.0 * x),
    "rmatmul_array": ((4, 4), lambda x: _W44 @ x),
    "radd_array": ((3, 4), lambda x: _C34 + F.relu(x)),
    "rsub_array": ((3, 4), lambda x: _C34 - F.relu(x)),
    "mul_buffers": ((3, 4), lambda x: F.relu(x) * x),
    "div_buffers": ((3, 4), lambda x: x / (F.relu(x) + 1.0)),
}


@pytest.mark.parametrize("name", sorted(REFLECTED_CASES))
def test_reflected_ops_eager_equals_compiled(name):
    shape, fn = REFLECTED_CASES[name]
    (x,) = _inputs([shape], seed=3)
    program = codo.compile(fn, shape, name=name, cache=None)
    got = np.asarray(program(x, jit=False))
    want = np.asarray(fn(x))
    np.testing.assert_array_equal(got, want)     # bit-exact, not just close


def test_reflected_array_operand_becomes_const_task():
    g = F.trace(lambda x: _W44 @ x, (4, 4), name="wx")
    const = [t for t in g.tasks if t.spec is not None
             and t.spec.kind == "const"]
    assert len(const) == 1
    np.testing.assert_array_equal(
        np.array(const[0].spec.attrs["value"], np.float32), _W44)
    # the constant value keys the compile cache: a different W, a new hash
    g2 = F.trace(lambda x: (_W44 + 1.0) @ x, (4, 4), name="wx")
    assert g.structural_hash() != g2.structural_hash()


def test_scalar_forms_key_cache_apart():
    a = F.trace(lambda x: 2.0 - x, (4,), name="s")
    b = F.trace(lambda x: 3.0 - x, (4,), name="s")
    assert a.structural_hash() != b.structural_hash()


# --------------------------------------------------------------------------
# Pass budgets (satellite: --enforce-budgets)
# --------------------------------------------------------------------------


def test_pass_budget_records_and_enforcement():
    opts = CodoOptions(pass_budgets={"schedule": 1e-9})
    c = codo_opt(dm.gemm(24, 24, 24), opts, cache=None)
    viol = c.diagnostics.budget_violations()
    assert viol and "schedule" in viol[0]
    assert any(r.over_budget for r in c.diagnostics.records)
    with pytest.warns(RuntimeWarning, match="pass budget exceeded"):
        got = enforce_pass_budgets([c.diagnostics])
    assert got == viol
    with pytest.raises(PassBudgetError):
        enforce_pass_budgets([c.diagnostics], strict=True)


def test_pass_budget_within_limit_is_quiet():
    opts = CodoOptions(pass_budgets={"schedule": 1e6})
    c = codo_opt(dm.gemm(24, 24, 24), opts, cache=None)
    assert c.diagnostics.budget_violations() == []
    assert enforce_pass_budgets([c.diagnostics], strict=True) == []


def test_pass_budgets_do_not_change_cache_key():
    assert (CodoOptions(pass_budgets={"fine": 0.5}).cache_key()
            == CodoOptions().cache_key())
    # ...but real option changes still do.
    assert CodoOptions(fine=False).cache_key() != CodoOptions().cache_key()


def test_pass_budgets_survive_options_roundtrip():
    opts = CodoOptions(pass_budgets={"fine": 0.5, "coarse": 0.25})
    back = CodoOptions.from_dict(opts.to_dict())
    assert back.pass_budgets == {"coarse": 0.25, "fine": 0.5}


# --------------------------------------------------------------------------
# npz input loading (satellite: serve --inputs)
# --------------------------------------------------------------------------


def test_load_input_env_validates(tmp_path):
    from repro.launch.serve import InputError, load_input_env
    g = F.trace(dm.gemm_fn, (6, 4), (4, 5), name="gemm")
    A, B = _inputs([(6, 4), (4, 5)])

    good = tmp_path / "good.npz"
    np.savez(good, A=A, B=B)
    env = load_input_env(str(good), g)
    assert set(env) == {"A", "B"} and env["A"].dtype == np.float32

    np.savez(tmp_path / "missing.npz", A=A)
    with pytest.raises(InputError, match="missing input"):
        load_input_env(str(tmp_path / "missing.npz"), g)

    np.savez(tmp_path / "shape.npz", A=A, B=B.T)
    with pytest.raises(InputError, match="shape"):
        load_input_env(str(tmp_path / "shape.npz"), g)

    np.savez(tmp_path / "unknown.npz", A=A, B=B, typo=A)
    with pytest.raises(InputError, match="unknown array names"):
        load_input_env(str(tmp_path / "unknown.npz"), g)


def test_load_input_env_normalizes_dtypes_before_validation(tmp_path):
    """Satellite: weak-dtype inputs (float64 under disabled x64, int
    labels) normalize to the buffer dtype and load; 0-d scalars and
    non-castable arrays report as InputError (CLI exit 2), never a raw
    traceback."""
    from repro.launch.serve import InputError, load_input_env
    g = F.trace(dm.gemm_fn, (6, 4), (4, 5), name="gemm")
    A, B = _inputs([(6, 4), (4, 5)])

    # float64 data under x64-disabled jax: accepted, cast to float32
    np.savez(tmp_path / "f64.npz", A=A.astype(np.float64),
             B=B.astype(np.float64))
    env = load_input_env(str(tmp_path / "f64.npz"), g)
    assert all(v.dtype == np.float32 for v in env.values())
    np.testing.assert_allclose(env["A"], A)

    # integer data casts too (dtype normalization precedes validation)
    np.savez(tmp_path / "int.npz", A=np.ones((6, 4), np.int64), B=B)
    assert load_input_env(str(tmp_path / "int.npz"), g)["A"].dtype \
        == np.float32

    # a Python scalar saved via np.savez arrives 0-d: clean InputError
    np.savez(tmp_path / "zerod.npz", A=3.0, B=B)
    with pytest.raises(InputError, match="0-d"):
        load_input_env(str(tmp_path / "zerod.npz"), g)

    # non-castable (string) data: InputError naming the dtypes
    np.savez(tmp_path / "str.npz", A=np.full((6, 4), "x"), B=B)
    with pytest.raises(InputError, match="does not cast"):
        load_input_env(str(tmp_path / "str.npz"), g)

    # not an npz archive at all
    (tmp_path / "junk.npz").write_text("not a zip")
    with pytest.raises(InputError, match="not a readable npz"):
        load_input_env(str(tmp_path / "junk.npz"), g)
    with pytest.raises(InputError, match="not a readable npz"):
        load_input_env(str(tmp_path / "missing-file.npz"), g)


def test_serve_cli_inputs_errors_exit_2(tmp_path, capsys):
    """The CLI contract: bad --inputs archives exit 2 with an error line,
    good ones serve."""
    import repro.launch.serve as serve
    program = codo.compile(dm.gemm_fn, (6, 4), (4, 5), name="gemm",
                           cache=None)
    art = tmp_path / "gemm.json"
    program.export(str(art))

    A, B = _inputs([(6, 4), (4, 5)])
    np.savez(tmp_path / "good.npz", A=A.astype(np.float64), B=B)
    rc = serve.main(["--artifact", str(art), "--requests", "1",
                     "--inputs", str(tmp_path / "good.npz")])
    assert rc == 0

    np.savez(tmp_path / "zerod.npz", A=2.0, B=B)
    rc = serve.main(["--artifact", str(art), "--requests", "1",
                     "--inputs", str(tmp_path / "zerod.npz")])
    captured = capsys.readouterr()
    assert rc == 2 and "error:" in captured.err and "0-d" in captured.err


# --------------------------------------------------------------------------
# Batch / process-pool composition (satellite: picklable traced workloads)
# --------------------------------------------------------------------------


def test_traced_workloads_pickle():
    wl = kernel_workloads()
    assert set(wl) == set(dm.KERNEL_BENCHES)
    jobs = [BatchJob(n, "opt5", wl[n], CodoOptions.opt5())
            for n in ("gemm", "atax")]
    rebuilt = pickle.loads(pickle.dumps(jobs))
    g = rebuilt[0].build()
    assert g.structural_hash() == dm.gemm().structural_hash()


def test_traced_workloads_through_process_pool():
    jobs = [BatchJob(n, "opt5", fn, CodoOptions.opt5())
            for n, fn in sorted(kernel_workloads().items())[:3]]
    results = codo_opt_batch(jobs, max_workers=2, cache=None,
                             executor="process")
    assert all(r.ok for r in results), [r.error for r in results]
    # spec-carrying results cross the pipe executable
    assert all(t.fn is not None
               for r in results for t in r.compiled.graph.tasks)


# --------------------------------------------------------------------------
# Smoke CLI (the CI compile-smoke job drives this cold/warm)
# --------------------------------------------------------------------------


def test_api_cli_cold_then_warm(tmp_path, capsys):
    from repro import api
    cache_dir = str(tmp_path / "cache")
    assert api.main(["residual_mlp", "--cache-dir", cache_dir]) == 0
    assert "cache_hit=False" in capsys.readouterr().out
    assert api.main(["residual_mlp", "--cache-dir", cache_dir]) == 0
    assert "cache_hit=True" in capsys.readouterr().out
