"""Off-chip transfer planning (§V-C) + cost-model property tests."""

import numpy as np

from repro.core import (DataflowGraph, ewise_task, graph_latency, host_manifest,
                        matmul_task, plan_offchip, task_cost)
from repro.core.costmodel import V5E
from repro.core.schedule import apply_degree
from repro.models import dataflow_models as dm


def test_channel_balancing():
    g = dm.vgg16(32)
    plan = plan_offchip(g, num_channels=8)
    assert len(plan.channel_bytes) == 8
    # greedy largest-first keeps the busiest channel within 2x of the mean
    mean = sum(plan.channel_bytes) / 8
    assert max(plan.channel_bytes) <= 2.0 * mean + 1
    assert 0.0 < plan.bandwidth_util <= 1.0


def test_burst_padding_for_narrow_buffers():
    g = DataflowGraph("narrow")
    g.buffer("w", (64, 3), kind="weight")     # 3-wide innermost: short burst
    plan = plan_offchip(g)
    assert "w" in plan.padded_shape           # padded to lane multiple
    assert plan.padded_shape["w"][-1] % 128 == 0


def test_host_manifest_lists_transfers():
    g = dm.gemm(64, 64, 64)
    plan = plan_offchip(g)
    text = host_manifest(g, plan)
    assert "h2d" in text and "burst=" in text


def test_parallel_degree_scales_compute():
    t = matmul_task("mm", "c", "a", "b", 128, 128, 128)
    g = DataflowGraph("g")
    g.buffer("a", (128, 128), kind="input")
    g.buffer("b", (128, 128), kind="weight")
    g.buffer("c", (128, 128), kind="output")
    g.add_task(t)
    c1 = task_cost(g, t).compute_cycles
    apply_degree(t, 16)
    c16 = task_cost(g, t).compute_cycles
    assert c16 <= c1 / 8                      # near-linear scaling


def test_memory_bound_floor():
    """Parallelism cannot push a task below its memory-bandwidth bound."""
    g = DataflowGraph("mb")
    g.buffer("x", (1024, 1024), kind="input")
    g.buffer("o", (1024, 1024), kind="output")
    t = ewise_task("copyish", "o", ["x"], (1024, 1024), flops_per_iter=0.1)
    g.add_task(t)
    base = task_cost(g, t)
    apply_degree(t, 4096)
    fast = task_cost(g, t)
    assert fast.latency >= base.memory_cycles * 0.99


def test_graph_latency_monotone_in_degree():
    g = dm.feed_forward(64, 128)
    lat1 = graph_latency(g, V5E).total_cycles
    for t in g.tasks:
        apply_degree(t, 8)
    lat8 = graph_latency(g, V5E).total_cycles
    assert lat8 < lat1
