"""Cost-gated routing + measured autotuning (the ISSUE-6 tentpole).

Covers the latency predictor against the recorded routing bench (rank
agreement, not absolute cycles), the CPU softmaxmm fallback the bench
motivated, the ``tuned > forced > predicted`` decision precedence with
its env overrides, the tuning database's ride through artifact v1.2 into
a fresh interpreter, and the lowering memo key's sensitivity to tuning
changes.  Kernel numerics and the matcher itself live in
``tests/test_routing.py``.
"""

import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core import CodoOptions, codo_opt
from repro.core.costmodel import estimate_chain, routing_backend, \
    routing_params
from repro.core.lowering import (LOWER_CACHE_STATS, clear_lower_cache,
                                 fusion_groups, lower)
from repro.core.routing import ROUTED_DECISIONS, XLA_FUSED, match_group
from repro.core.tuning import (TuningRecord, autotune_compiled,
                               chain_signature, default_tuning_db,
                               reset_default_tuning_db)
from repro.kernels import register_all
from repro.models import dataflow_models as dm

register_all()

REPO = Path(__file__).resolve().parents[1]
BENCH_JSON = REPO / "results" / "bench" / "routing_groups.json"


@pytest.fixture(autouse=True)
def _clean_tuning_db():
    """Measured decisions override the predictor, so every test here gets
    (and leaves behind) an empty process tuning database."""
    reset_default_tuning_db()
    yield
    reset_default_tuning_db()


def _compile(graph, budget=64):
    return codo_opt(graph, CodoOptions.preset("opt5", budget_units=budget),
                    cache=None)


# --------------------------------------------------------------------------
# The predictor vs the recorded bench
# --------------------------------------------------------------------------


@pytest.mark.skipif(not BENCH_JSON.exists(),
                    reason="no recorded routing bench")
def test_predictor_ranks_chains_like_recorded_bench():
    """The gate doesn't need cycle-accurate latencies — it needs the
    *ordering* of chains by cost to agree with what the machine measured.

    Judged as pairwise rank concordance within each workload, and only
    over pairs where both sides show a real margin: resnet stages are
    constant-FLOPs by design, so the model prices them as near-ties that
    CPU wall-clock (which favors large-spatial layers) legitimately
    scrambles; and the cycle→ms scale differs per op family, so
    cross-workload pairs are not comparable."""
    doc = json.loads(BENCH_JSON.read_text())
    quick = bool(doc.get("quick"))
    builds = {
        "gpt2_block": (lambda: dm.gpt2_block(S=64)) if quick
        else (lambda: dm.gpt2_block()),
        "resnet18": lambda: dm.resnet18(32),
    }
    predicted = {}
    for wname, build in builds.items():
        c = codo_opt(build(), CodoOptions.preset("opt5"), cache=None)
        impl = c.buffer_plan.impl if c.buffer_plan else {}
        for g in fusion_groups(c.graph, impl):
            for pat, tasks in match_group(c.graph, g.tasks, impl):
                est = estimate_chain(c.graph, tasks, pat.name)
                key = (wname, tuple(t.name for t in tasks))
                predicted[key] = est.generic_cycles
    points = []
    for r in doc["records"]:
        key = (r["workload"], tuple(r["tasks"]))
        if key in predicted:
            points.append((r["workload"], predicted[key],
                           float(r["xla_ms"])))
    assert len(points) >= 6, "bench records no longer line up with matcher"

    judged = concordant = 0
    for i in range(len(points)):
        for j in range(i + 1, len(points)):
            (wa, pa, ma), (wb, pb, mb) = points[i], points[j]
            if wa != wb:
                continue
            if max(pa, pb) < 1.2 * min(pa, pb):      # predicted near-tie
                continue
            if max(ma, mb) < 1.3 * min(ma, mb):      # measured noise band
                continue
            judged += 1
            concordant += (pa > pb) == (ma > mb)
    assert judged >= 5, f"only {judged} decisive pairs"
    assert concordant / judged >= 0.8, \
        f"predictor agrees on {concordant}/{judged} decisive pairs"


def test_softmaxmm_tail_stays_generic_on_cpu(monkeypatch):
    """The satellite bugfix, pinned: the bench measures the softmaxmm
    kernel at ~0.97x on CPU, so the calibrated gate must route the
    attention tail to generic XLA there — at any size.  In gpt2_block the
    full-chain ``flashattn.mha`` now supersedes this tail (see
    test_flashattn_supersedes_softmaxmm_in_gpt2), so the bare tail is
    exercised on a graph whose chain *starts* at the softmax."""
    monkeypatch.delenv("CODO_FORCE_PALLAS", raising=False)
    monkeypatch.delenv("CODO_DISABLE_PALLAS", raising=False)
    monkeypatch.delenv("CODO_ROUTING_CALIBRATION", raising=False)
    monkeypatch.setenv("CODO_BACKEND", "cpu")
    # CPU has no spill/overlap terms, so the win condition reduces to
    # eff * (1 + slack) > 1; softmaxmm's calibrated 0.97 keeps it losing
    # regardless of chain size.
    p = routing_params("cpu")
    assert p.eff("streamfuse.softmaxmm") * (1.0 + p.slack) < 1.0
    from repro.core.frontend import GB
    b = GB("sm_tail")
    s = b.input("s", (64, 64))
    v = b.input("v", (64, 64))
    b.mark_output(b.matmul(b.softmax(s), v))
    c = _compile(b.g)
    low = lower(c, jit=False)
    assert all(r.kernel != "streamfuse.softmaxmm"
               for g in low.groups for r in g.routes)
    rej = [r for g in low.groups for r in g.rejected
           if r.kernel == "streamfuse.softmaxmm"]
    assert rej, "the softmaxmm chain must still structurally match"
    assert all(r.decision == "predicted-loss" for r in rej)
    # ...and the verdict rides on the diagnostics with both estimates
    entries = c.diagnostics.group_kernels.values()
    assert any(any(rr["kernel"] == "streamfuse.softmaxmm"
                   and rr["decision"] == "predicted-loss"
                   for rr in e["rejected"]) for e in entries)


def test_calibration_env_knob_refits_efficiency(monkeypatch, tmp_path):
    doc = {"backend": "cpu", "records": [
        {"kernel": "streamfuse.softmaxmm", "speedup": 1.5},
        {"kernel": "streamfuse.softmaxmm", "speedup": 1.5},
    ]}
    path = tmp_path / "calib.json"
    path.write_text(json.dumps(doc))
    monkeypatch.setenv("CODO_ROUTING_CALIBRATION", str(path))
    p = routing_params("cpu")
    assert p.eff("streamfuse.softmaxmm") == pytest.approx(1.5, rel=1e-3)
    # patterns absent from the document keep their defaults
    assert p.eff("streamfuse.conv") == pytest.approx(0.99)


# --------------------------------------------------------------------------
# Decision precedence + env overrides
# --------------------------------------------------------------------------


def test_force_and_disable_override_precedence(monkeypatch):
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")
    c = _compile(dm.feed_forward(16, 32))       # below the win threshold
    low = lower(c, jit=False)
    routed = [r for g in low.groups for r in g.routes]
    assert routed, "CODO_FORCE_PALLAS must route gate-rejected chains"
    assert all(r.decision == "forced" for r in routed)
    assert all(r.decision in ROUTED_DECISIONS for r in routed)

    monkeypatch.setenv("CODO_DISABLE_PALLAS", "1")   # disable beats force
    low2 = lower(c, jit=False)
    assert all(not g.routes for g in low2.groups)
    assert all(g.kernel == XLA_FUSED for g in low2.groups)
    assert any(g.decision == "disabled" for g in low2.groups)


def test_tuning_db_change_flips_memo_key_and_decision():
    """A measured entry must (a) override the predictor's verdict and
    (b) change the lowering memo key, so stale programs built before the
    measurement can never be served after it."""
    c = _compile(dm.feed_forward(16, 32))
    lower(c, jit=False)          # assigns fused_group ids (hash settles)
    clear_lower_cache()
    low = lower(c, jit=False)
    assert LOWER_CACHE_STATS["misses"] == 1
    rej = [r for g in low.groups for r in g.rejected
           if r.kernel == "streamfuse.mmchain"]
    assert rej and rej[0].decision == "predicted-loss"
    lower(c, jit=False)                      # same key: a hit
    assert LOWER_CACHE_STATS["hits"] == 1

    tasks = [c.graph.task(n) for n in rej[0].tasks]
    default_tuning_db().update(TuningRecord(
        signature=chain_signature(c.graph, tasks),
        backend=routing_backend(), hw=c.options.hw.name,
        pattern="streamfuse.mmchain", choice="pallas",
        routed_ms=1.0, generic_ms=2.0))
    low2 = lower(c, jit=False)               # digest changed: re-lower
    assert LOWER_CACHE_STATS["misses"] == 2
    tuned = [r for g in low2.groups for r in g.routes
             if r.kernel == "streamfuse.mmchain"]
    assert tuned and tuned[0].decision == "tuned"
    assert tuned[0].measured_speedup == pytest.approx(2.0)

    reset_default_tuning_db()                # back to the empty-db digest:
    low3 = lower(c, jit=False)               # the pre-tuning entry is reused
    assert LOWER_CACHE_STATS["hits"] == 2
    assert all(r.kernel != "streamfuse.mmchain"
               for g in low3.groups for r in g.routes)


# --------------------------------------------------------------------------
# Measured autotune riding artifact v1.2 into a fresh interpreter
# --------------------------------------------------------------------------


def _fresh_interpreter(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    for k in ("CODO_TUNING_DB", "CODO_FORCE_PALLAS", "CODO_DISABLE_PALLAS"):
        env.pop(k, None)
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env)


def test_tuning_roundtrips_through_artifact_in_fresh_interpreter(tmp_path):
    from repro.core import export_artifact
    c = _compile(dm.feed_forward(16, 32))
    lower(c, jit=False)
    records = autotune_compiled(c, repeats=2, warmup=1)
    assert records and len(default_tuning_db()) >= 1
    assert all(r.choice in ("pallas", XLA_FUSED) for r in records)

    doc = export_artifact(c)
    assert doc["schema_version"] == "1.5"
    assert doc["tuning"] and len(doc["tuning"]["entries"]) >= 1
    path = tmp_path / "ff.json"
    path.write_text(json.dumps(doc))

    proc = _fresh_interpreter(f"""
        import json
        from repro.core import import_artifact
        from repro.core.lowering import lower
        from repro.core.tuning import default_tuning_db
        from repro.models.dataflow_models import random_inputs

        doc = json.loads(open({str(path)!r}).read())
        assert len(default_tuning_db()) == 0
        c = import_artifact(doc)
        db = default_tuning_db()
        want = {{e["signature"] for e in doc["tuning"]["entries"]}}
        got = {{r.signature for r in db.entries.values()}}
        assert want <= got, (want, got)
        # the imported measurement drives routing in this process too
        low = lower(c, jit=False)
        decisions = {{r.decision for g in low.groups
                      for r in (*g.routes, *g.rejected)}}
        assert decisions & {{"tuned", "tuned-generic"}}, decisions
        low(random_inputs(c.graph))              # still executes
        print("ROUNDTRIP-OK", len(db))
    """)
    assert proc.returncode == 0, proc.stderr
    assert "ROUNDTRIP-OK" in proc.stdout


def test_gate_retry_remeasures_only_offenders(monkeypatch):
    """The CI gate re-times first-pass offenders solo at a higher
    best-of count and judges the fresh numbers — a noise blip converges
    back within tolerance, a real regression fails twice."""
    from benchmarks import routing_bench as rb

    def rec(workload, gid, kernel, speedup, routed=True):
        return {"workload": workload, "gid": gid, "kernel": kernel,
                "tasks": ["a", "b"], "decision": "predicted-win",
                "routed": routed, "speedup": speedup,
                "pallas_ms": 1.0, "xla_ms": speedup,
                "predicted_speedup": 1.0,
                "predicted_routed_cycles": 1.0,
                "predicted_generic_cycles": 1.0}

    doc = {"backend": "cpu", "tolerance": 0.05, "quick": True,
           "records": [rec("resnet18", 1, "streamfuse.conv", 1.02),
                       rec("resnet18", 6, "streamfuse.conv", 0.92),
                       rec("gpt2_block", 0, "streamfuse.softmaxmm",
                           0.80, routed=False)]}
    # Only the routed under-tolerance record is an offender; the
    # rejected softmaxmm chain is measured but never judged.
    assert len(rb.check_gate(doc)) == 1

    seen = []

    def fake_bench(name, build, *, warmup, reps, only=None):
        seen.append((name, reps, only))
        return [rec(name, gid, kernel, 0.99)
                for gid, kernel, _tasks in sorted(only)]

    monkeypatch.setattr(rb, "bench_workload", fake_bench)
    doc = rb.remeasure_offenders(doc)
    # One solo re-run, offender only, at the recheck best-of count.
    assert seen == [("resnet18", rb.RECHECK_REPS,
                     {(6, "streamfuse.conv", ("a", "b"))})]
    by_gid = {r["gid"]: r for r in doc["records"]
              if r["workload"] == "resnet18"}
    assert by_gid[6]["speedup"] == 0.99      # patched in
    assert by_gid[1]["speedup"] == 1.02      # untouched
    assert rb.check_gate(doc) == []

    # A repeat offender stays failed.
    doc["records"][1]["speedup"] = 0.90
    monkeypatch.setattr(
        rb, "bench_workload",
        lambda name, build, *, warmup, reps, only=None:
        [rec(name, gid, kernel, 0.90)
         for gid, kernel, _tasks in sorted(only)])
    doc = rb.remeasure_offenders(doc)
    assert len(rb.check_gate(doc)) == 1
