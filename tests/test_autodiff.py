"""Graph-level autodiff (ISSUE 10): declarative VJP rules, the backward
and AdamW-update graphs, the compiled train step, and its v1.5 artifact.

Covers the acceptance criteria:

* per-op parametrized gradient checks — every kind in
  ``differentiable_ops()`` (incl. the ``rglru_scan``/``ssd_scan``
  recurrences) has its registry VJP checked against ``jax.grad`` of the
  registered forward impl;
* the compiled GPT-2-block train step matches eager ``jax.grad`` +
  ``training.optimizer.adamw_update`` within the documented fp band
  (gradients rtol 2e-3/atol 1e-4; update math *given identical
  gradients* is bit-tight);
* the backward graph carries ≥1 cost-gate-approved routed chain
  (``streamfuse.mmgrad``; forced on CPU via ``CODO_FORCE_PALLAS`` since
  the gate predicts a loss at CPU efficiency);
* the v1.5 train-step artifact reloads executable in a fresh
  interpreter;
* the compiled training driver (``train_compiled``/``resume_compiled``)
  keeps the checkpoint/restart semantics of the jitted loop;
* ``launch/train.py`` is a warn+delegate shim onto
  ``repro.training.cli``.
"""

import json
import subprocess
import sys
import warnings
from pathlib import Path

import numpy as np
import pytest

import repro.api as codo
from repro.core.autodiff import (AutodiffError, _BwdBuilder, build_backward,
                                 build_update, opt_attrs)
from repro.core.frontend import GB
from repro.core.ops import differentiable_ops, has_vjp
from repro.core.routing import ROUTED_DECISIONS, route_plan
from repro.kernels import register_all
from repro.models.dataflow_models import gpt2_block_loss_fn

register_all()

RNG = np.random.default_rng(11)
SRC = Path(__file__).resolve().parents[1] / "src"


# --------------------------------------------------------------------------
# Per-op gradient checks: registry VJP vs jax.grad of the registered impl
# --------------------------------------------------------------------------

# kind -> case.  ``ins``/``outs`` are shapes; ``attrs``/``op`` feed the
# OpSpec; ``env`` optionally overrides the default standard-normal inputs
# (domain restrictions: positive denominators, contractive decays).
def _pos(shape):
    return RNG.uniform(0.5, 1.5, shape).astype(np.float32)


def _std(shape):
    return RNG.standard_normal(shape).astype(np.float32)


CASES = {
    "identity": dict(ins=[(4, 5)], outs=[(4, 5)]),
    "dup": dict(ins=[(4, 5)], outs=[(4, 5), (4, 5)]),
    "relu": dict(ins=[(4, 5)], outs=[(4, 5)]),
    "gelu": dict(ins=[(4, 5)], outs=[(4, 5)]),
    "add": dict(ins=[(4, 5), (4, 5)], outs=[(4, 5)]),
    "vadd": dict(ins=[(4, 5), (4, 5)], outs=[(4, 5)],
                 attrs={"alpha": 1.5, "beta": -0.5}),
    "scale": dict(ins=[(4, 5)], outs=[(4, 5)], attrs={"s": 1.7}),
    "affine": dict(ins=[(4, 5)], outs=[(4, 5)], attrs={"a": -1.0, "b": 0.3}),
    "divc": dict(ins=[(4, 5)], outs=[(4, 5)], attrs={"c": 3.0}),
    "rdivc": dict(ins=[(4, 5)], outs=[(4, 5)], attrs={"c": 2.0}, env=_pos),
    "div": dict(ins=[(4, 5), (4, 5)], outs=[(4, 5)], env=_pos),
    "mul": dict(ins=[(4, 5), (4, 5)], outs=[(4, 5)]),
    "matmul": dict(ins=[(4, 3), (3, 5)], outs=[(4, 5)], op="matmul"),
    "mv": dict(ins=[(4, 3), (3,)], outs=[(4,)], op="matmul",
               loop_shape=(4, 3)),
    "transpose": dict(ins=[(4, 5)], outs=[(5, 4)], op="copy"),
    "reshape": dict(ins=[(4, 5)], outs=[(2, 10)],
                    attrs={"shape": (2, 10)}, op="copy"),
    "concat": dict(ins=[(2, 5), (3, 5)], outs=[(5, 5)], attrs={"axis": 0}),
    "split": dict(ins=[(5, 4)], outs=[(2, 4), (3, 4)],
                  attrs={"axis": 0, "sizes": (2, 3)}),
    "slice": dict(ins=[(5, 6)], outs=[(2, 3)],
                  attrs={"starts": (1, 2), "sizes": (2, 3)}, op="copy"),
    "softmax": dict(ins=[(4, 5)], outs=[(4, 5)], attrs={"axis": -1}),
    "pad2d": dict(ins=[(1, 2, 6, 6)], outs=[(1, 2, 8, 8)],
                  attrs={"pad": 1}, op="copy"),
    "fill_interior": dict(ins=[(1, 2, 6, 6)], outs=[(1, 2, 8, 8)],
                          attrs={"pad": 1}, op="copy"),
    "conv2d": dict(ins=[(1, 2, 6, 6), (3, 2, 3, 3)], outs=[(1, 3, 4, 4)],
                   attrs={"stride": 1, "groups": 1}, op="conv"),
    "maxpool2d": dict(ins=[(1, 2, 6, 6)], outs=[(1, 2, 3, 3)],
                      attrs={"k": 2}, op="pool"),
    "mean": dict(ins=[(4, 5)], outs=[(4,)], attrs={"axes": (1,)}, op="pool",
                 loop_shape=(4, 5)),
    "mean_all": dict(ins=[(4, 5)], outs=[(1, 1)], op="pool"),
    "rglru_scan": dict(
        ins=[(2, 5, 3), (2, 5, 3)], outs=[(2, 5, 3)], op="scan",
        env=lambda shape: RNG.uniform(-0.8, 0.8, shape).astype(np.float32)),
    "ssd_scan": dict(
        ins=[(4, 2, 3, 2), (4, 2, 1, 1)], outs=[(4, 2, 3, 2)], op="scan",
        env=lambda shape: RNG.uniform(0.2, 0.9, shape).astype(np.float32)),
    # no-operand constants: no cotangents to produce (rule returns {});
    # checked through a graph where they feed a differentiable op.
    "zeros": dict(special="zeros"),
    "const": dict(special="const"),
}


def _case_graph(kind, case):
    """A one-op forward graph for ``kind`` (inputs x0..xn, op outputs
    marked as graph outputs), built with the same generalized emitter the
    autodiff rules use — the numerics come from the registry impl either
    way."""
    gb = GB(f"{kind}_case")
    b = _BwdBuilder(gb)
    if case.get("special") == "zeros":
        x = gb.input("x0", (4, 5))
        z = b.zeros((4, 5))
        gb.mark_output(gb.add(x, z))
        return gb.g, [x]
    if case.get("special") == "const":
        x = gb.input("x0", (4, 5))
        value = tuple(map(tuple, _std((4, 5)).tolist()))
        c = b.emit("const", (), ((4, 5),),
                   {"value": value, "dtype": "float32"}, op="copy")[0]
        gb.mark_output(gb.mul(x, c))
        return gb.g, [x]
    ins = [gb.input(f"x{i}", tuple(shp))
           for i, shp in enumerate(case["ins"])]
    outs = b.emit(kind, tuple(ins), case["outs"], case.get("attrs"),
                  op=case.get("op", "ewise"),
                  loop_shape=case.get("loop_shape"))
    for o in outs:
        gb.mark_output(o)
    g = gb.g
    g.validate()
    return g, ins


def test_vjp_case_coverage():
    """Every differentiable op kind has a gradient-check case (and every
    case names a registered rule) — new rules must arrive with a check."""
    assert set(CASES) == set(differentiable_ops())
    assert all(has_vjp(k) for k in CASES)


@pytest.mark.parametrize("kind", sorted(CASES))
def test_op_vjp_matches_jax_grad(kind):
    import jax
    import jax.numpy as jnp

    case = CASES[kind]
    src, ins = _case_graph(kind, case)
    env_fn = case.get("env", _std)
    env = {n: env_fn(tuple(src.buffers[n].shape)) for n in ins}

    bb = build_backward(src, wrt=list(ins))
    # Residual intermediates become forward outputs (shared, the train-
    # step wiring); inputs re-read by the backward come from ``env``.
    fwd = src.copy()
    for r in bb.residuals:
        if fwd.buffers[r].kind == "intermediate":
            fwd.buffers[r].kind = "output"
    fouts = fwd.execute(env)

    seeds = {s: _std(tuple(src.buffers[o].shape))
             for o, s in bb.seeds.items()}
    benv = dict(seeds)
    for r in bb.residuals:
        benv[r] = fouts[r] if r in fouts else env[r]
    bouts = bb.graph.execute(benv)
    got = {w: np.asarray(bouts[bb.grads[w]]) for w in ins}

    def scalar(ps):
        out = src.execute({**env, **ps})
        return sum((out[o].astype(jnp.float32)
                    * seeds[bb.seeds[o]]).sum() for o in bb.seeds)

    ref = jax.grad(scalar)({w: jnp.asarray(env[w]) for w in ins})
    for w in ins:
        np.testing.assert_allclose(
            got[w], np.asarray(ref[w]), rtol=1e-4, atol=1e-5,
            err_msg=f"{kind}: grad wrt {w} diverged from jax.grad")


def test_fused_task_is_rejected():
    """Autodiff runs on the pre-pass source graph; a post-fusion
    composite spec has no VJP rule and is rejected with guidance."""
    gb = GB("fused_rej")
    b = _BwdBuilder(gb)
    x = gb.input("x", (4, 4))
    (o,) = b.emit("fused", (x,), ((4, 4),), {"ops": ("relu", "scale")})
    gb.mark_output(o)
    with pytest.raises(AutodiffError, match="fused composite"):
        build_backward(gb.g, wrt=[x])


# --------------------------------------------------------------------------
# Update graph vs training.optimizer (bit-tight with identical grads)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("step_no", [0, 500])
def test_update_graph_matches_adamw(step_no):
    from repro.training.optimizer import OptConfig, adamw_update

    oc = OptConfig(lr=1e-3, warmup_steps=10, total_steps=1000)
    shapes = {"wa": (4, 5), "wb": (7,)}
    upd = build_update(shapes, oc)
    params = {w: _std(s) for w, s in shapes.items()}
    grads = {w: _std(s) for w, s in shapes.items()}
    state = {"m": {w: _std(s) * 0.01 for w, s in shapes.items()},
             "v": {w: np.abs(_std(s)) * 0.01 for w, s in shapes.items()},
             "step": np.asarray(step_no, np.int32)}

    env = {"step": np.float32(step_no).reshape(1, 1)}
    for w in shapes:
        env[w] = params[w]
        env[f"grad_{w}"] = grads[w]
        env[f"m_{w}"] = state["m"][w]
        env[f"v_{w}"] = state["v"][w]
    outs = upd.execute(env)

    ref_p, ref_s, ref_m = adamw_update(grads, state, params, oc)
    for w in shapes:
        np.testing.assert_allclose(np.asarray(outs[f"new_{w}"]),
                                   np.asarray(ref_p[w]), rtol=0, atol=1e-6,
                                   err_msg=f"new_{w}")
        np.testing.assert_allclose(np.asarray(outs[f"new_m_{w}"]),
                                   np.asarray(ref_s["m"][w]), rtol=0,
                                   atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(outs["grad_norm"]).reshape(()),
        np.asarray(ref_m["grad_norm"]), rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(outs["lr"]).reshape(()),
                               np.asarray(ref_m["lr"]), rtol=1e-6)
    assert int(np.asarray(outs["new_step"]).reshape(())) == step_no + 1


def test_opt_attrs_normalization():
    from repro.training.optimizer import OptConfig
    assert opt_attrs(None)["lr"] == pytest.approx(3e-4)
    assert opt_attrs({"lr": 1e-3})["lr"] == pytest.approx(1e-3)
    assert opt_attrs(OptConfig(lr=2e-3))["lr"] == pytest.approx(2e-3)
    with pytest.raises(AutodiffError, match="unknown optimizer"):
        opt_attrs({"learning_rate": 1e-3})


# --------------------------------------------------------------------------
# Compiled GPT-2-block train step: the tentpole acceptance path
# --------------------------------------------------------------------------


@pytest.fixture(scope="module")
def gpt2_step():
    return codo.compile(gpt2_block_loss_fn, (32, 64), (32, 64), grad=True,
                        name="gpt2_block_loss")


def test_train_step_matches_eager_jax_grad(gpt2_step):
    x, t = _std((32, 64)), _std((32, 64))
    # the documented fp band: loss + grads vs eager jax.grad
    gpt2_step.verify(x, t)


def test_train_step_update_bit_tight_with_same_grads(gpt2_step):
    from repro.training.optimizer import OptConfig, adamw_update

    x, t = _std((32, 64)), _std((32, 64))
    params = gpt2_step.init_params()
    opt_state = gpt2_step.init_opt_state(params)
    loss, grads = gpt2_step.value_and_grad(x, t, params=params)
    new_params, new_state, metrics = gpt2_step.step(params, opt_state, x, t)
    # Same-gradient oracle: the update arithmetic itself is bit-tight
    # (the fp band lives in the gradients, not the optimizer math).
    g_np = {w: np.asarray(g) for w, g in grads.items()}
    ref_p, ref_s, ref_m = adamw_update(
        g_np, {"m": opt_state["m"], "v": opt_state["v"],
               "step": opt_state["step"]}, params, OptConfig())
    for w in gpt2_step.param_names:
        np.testing.assert_allclose(np.asarray(new_params[w]),
                                   np.asarray(ref_p[w]), rtol=0, atol=1e-6,
                                   err_msg=f"post-update {w}")
    np.testing.assert_allclose(float(metrics["grad_norm"]),
                               float(ref_m["grad_norm"]), rtol=1e-5)
    np.testing.assert_allclose(float(metrics["lr"]), float(ref_m["lr"]),
                               rtol=0, atol=0)
    assert int(new_state["step"]) == 1


def test_value_and_grad_method(gpt2_step):
    prog = codo.compile(gpt2_block_loss_fn, (32, 64), (32, 64),
                        name="gpt2_block_loss")
    step = prog.value_and_grad()
    assert sorted(step.param_names) == sorted(gpt2_step.param_names)
    x, t = _std((32, 64)), _std((32, 64))
    l1, g1 = gpt2_step.value_and_grad(x, t, params=gpt2_step.init_params())
    l2, g2 = step.value_and_grad(x, t, params=step.init_params())
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)
    for w in step.param_names:
        np.testing.assert_allclose(np.asarray(g1[w]), np.asarray(g2[w]),
                                   rtol=1e-6, atol=1e-7)


def test_grad_kwargs_guardrails():
    with pytest.raises(codo.TraceError, match="grad=True"):
        codo.compile(gpt2_block_loss_fn, (8, 16), (8, 16), wrt=["wfc3"])


def test_backward_routes_mmgrad_chain(monkeypatch):
    """≥1 cost-gate-approved routed chain in the backward graph.  On CPU
    the gate prices streamfuse.mmgrad at a predicted loss, so the chain
    is forced via CODO_FORCE_PALLAS — decision "forced" is in
    ROUTED_DECISIONS, the acceptance path."""
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")
    step = codo.compile(gpt2_block_loss_fn, (32, 64), (32, 64), grad=True,
                        cache=None, name="gpt2_block_loss_routed")
    bwd = step.backward.compiled
    impl = bwd.buffer_plan.impl if bwd.buffer_plan else {}
    plan = route_plan(bwd.graph, impl)
    routed = [r for e in plan for r in e["routes"]
              if r["kernel"] == "streamfuse.mmgrad"
              and r["decision"] in ROUTED_DECISIONS]
    assert routed, f"no routed mmgrad chain in {json.dumps(plan, indent=1)}"
    # routed numerics hold: the interpret-mode kernels run under verify
    x, t = _std((32, 64)), _std((32, 64))
    step.verify(x, t)


# --------------------------------------------------------------------------
# v1.5 train-step artifact
# --------------------------------------------------------------------------


def test_train_step_artifact_roundtrip(gpt2_step, tmp_path):
    path = tmp_path / "train_step.json"
    doc = gpt2_step.export(path, weights=True)
    assert doc["schema_version"] == "1.5"
    assert doc["kind"] == "train_step"
    assert set(doc["phases"]) == {"forward", "backward", "update"}
    assert doc["provenance"]["origin"].startswith("traced:")

    loaded = codo.load(path)
    assert sorted(loaded.param_names) == sorted(gpt2_step.param_names)
    x, t = _std((32, 64)), _std((32, 64))
    params = gpt2_step.init_params()
    l1, g1 = gpt2_step.value_and_grad(x, t, params=params)
    l2, g2 = loaded.value_and_grad(x, t, params=loaded.init_params())
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)
    for w in gpt2_step.param_names:
        np.testing.assert_allclose(np.asarray(g1[w]), np.asarray(g2[w]),
                                   rtol=1e-6, atol=1e-7)
    # re-export preserves the stored provenance verbatim
    assert loaded.export()["provenance"] == doc["provenance"]


def test_train_step_artifact_fresh_interpreter(gpt2_step, tmp_path):
    """The acceptance criterion: the artifact reloads executable in a
    fresh interpreter (no trace, no compile, registry-only numerics)."""
    path = tmp_path / "train_step.json"
    gpt2_step.export(path, weights=True)
    code = (
        "import numpy as np\n"
        "import repro.api as codo\n"
        f"step = codo.load({str(path)!r})\n"
        "rng = np.random.default_rng(0)\n"
        "x = rng.standard_normal((32, 64)).astype(np.float32)\n"
        "t = rng.standard_normal((32, 64)).astype(np.float32)\n"
        "p = step.init_params()\n"
        "np_, ns, m = step.step(p, step.init_opt_state(p), x, t)\n"
        "print('LOSS', float(m['loss']), int(ns['step']))\n")
    out = subprocess.run([sys.executable, "-c", code], text=True,
                         capture_output=True, env={"PYTHONPATH": str(SRC),
                                                   "JAX_PLATFORMS": "cpu",
                                                   "PATH": "/usr/bin:/bin"})
    assert out.returncode == 0, out.stderr
    tag, loss, stepno = out.stdout.split()[-3:]
    assert tag == "LOSS" and int(stepno) == 1
    # same numbers as in-process on the same deterministic batch
    rng = np.random.default_rng(0)
    x = rng.standard_normal((32, 64)).astype(np.float32)
    t = rng.standard_normal((32, 64)).astype(np.float32)
    p = gpt2_step.init_params()
    _, _, metrics = gpt2_step.step(p, gpt2_step.init_opt_state(p), x, t)
    np.testing.assert_allclose(float(loss), float(metrics["loss"]),
                               rtol=1e-6)


def test_single_design_provenance_diff(tmp_path):
    from repro.core.artifact import diff_artifacts
    from repro.core.compiler import CodoOptions

    a = codo.compile(gpt2_block_loss_fn, (8, 16), (8, 16),
                     name="prov_case").export(tmp_path / "a.json")
    b = codo.compile(gpt2_block_loss_fn, (8, 16), (8, 16),
                     name="prov_case",
                     options=CodoOptions.preset("opt1")).export(
                         tmp_path / "b.json")
    c = codo.compile(gpt2_block_loss_fn, (8, 32), (8, 32),
                     name="prov_case").export(tmp_path / "c.json")
    assert diff_artifacts(a, a) == []
    same_src = [d for d in diff_artifacts(a, b) if d.startswith("provenance")]
    assert same_src and "same source, different pipeline" in same_src[0]
    diff_src = [d for d in diff_artifacts(a, c) if d.startswith("provenance")]
    assert diff_src and "different source" in diff_src[0]


# --------------------------------------------------------------------------
# Compiled training driver + launcher shim
# --------------------------------------------------------------------------


def test_train_compiled_resume_semantics(gpt2_step, tmp_path):
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.training.train_loop import (SimulatedFailure, resume_compiled,
                                           train_compiled)

    rng = np.random.default_rng(5)

    def batch_fn(i):
        x = rng.standard_normal((32, 64)).astype(np.float32)
        return x, 0.5 * x

    ckpt = Checkpointer(str(tmp_path / "ckpt"))
    with pytest.raises(SimulatedFailure):
        train_compiled(gpt2_step, steps=6, batch_fn=batch_fn,
                       checkpointer=ckpt, checkpoint_every=2, fail_at=5)
    ckpt.wait()
    assert ckpt.steps()
    params, opt_state, report = resume_compiled(
        gpt2_step, ckpt, steps=6, batch_fn=batch_fn, checkpoint_every=2,
        verify_every=3)
    ckpt.wait()
    assert report.steps_done == 6
    assert int(opt_state["step"]) == 6
    assert len(report.losses) == 2          # resumed from step 4
    assert report.step_times


def test_launch_train_shim_warns_and_delegates():
    for mod in ("repro.launch.train",):
        sys.modules.pop(mod, None)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import repro.launch.train as shim
    assert any(issubclass(x.category, DeprecationWarning) and
               "repro.training.cli" in str(x.message) for x in w)
    from repro.training import cli
    assert shim.main is cli.main
