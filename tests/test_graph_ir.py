"""IR unit tests: graph construction, toposort, access signatures."""

import numpy as np
import pytest

from repro.core import (Access, Buffer, DataflowGraph, Loop, Task, access_sig,
                        arrival_order, conv2d_task, ewise_task, idx,
                        matmul_task, pool_task)
from repro.core.graph import GraphError
from repro.core.patterns import index_dims, reduction_dims


def _mini_graph():
    g = DataflowGraph("mini")
    g.buffer("a", (4, 4), kind="input")
    g.buffer("b", (4, 4))
    g.buffer("c", (4, 4), kind="output")
    g.add_task(ewise_task("t1", "b", ["a"], (4, 4),
                          fn=lambda env: {"b": env["a"] + 1}))
    g.add_task(ewise_task("t2", "c", ["b"], (4, 4),
                          fn=lambda env: {"c": env["b"] * 2}))
    return g


def test_toposort_and_execute():
    g = _mini_graph()
    order = [t.name for t in g.toposort()]
    assert order == ["t1", "t2"]
    out = g.execute({"a": np.zeros((4, 4))})
    assert np.allclose(out["c"], 2.0)


def test_cycle_detection():
    g = DataflowGraph("cyc")
    g.buffer("a", (2,))
    g.buffer("b", (2,))
    g.add_task(ewise_task("t1", "b", ["a"], (2,)))
    g.add_task(ewise_task("t2", "a", ["b"], (2,)))
    with pytest.raises(GraphError):
        g.toposort()


def test_validate_rank_mismatch():
    g = DataflowGraph("bad")
    g.buffer("a", (2, 2))
    g.buffer("o", (2,))
    t = Task("t", [Loop("i", 2)], [Access("a", (idx("i"),), False)],
             [Access("o", (idx("i"),), True)])
    g.add_task(t)
    with pytest.raises(GraphError):
        g.validate()


def test_matmul_signature():
    t = matmul_task("mm", "c", "a", "b", m=8, n=4, k=16)
    w = t.writes_to("c")[0]
    assert index_dims(t, w) == ["m", "n"]
    assert reduction_dims(t, w) == ["k"]
    sig = access_sig(t, w)
    assert sig.distinct == 32 and sig.total == 8 * 4 * 16
    assert sig.repeats


def test_conv_window_detection():
    t = conv2d_task("cv", "y", "x", "w", n=1, co=2, ci=3, h=8, w=8, kh=3, kw=3)
    r = t.reads_from("x")[0]
    sig = access_sig(t, r)
    assert sig.window                     # overlapping stencil
    # span of (h,1)+(kh,1): 8+3-1 = 10 per spatial dim
    assert sig.distinct == 1 * 3 * 10 * 10


def test_strided_pool_not_window():
    t = pool_task("p", "y", "x", n=1, c=2, oh=4, ow=4, k=2)
    r = t.reads_from("x")[0]
    sig = access_sig(t, r)
    assert not sig.window                 # stride-k windows don't overlap
    assert sig.distinct == 1 * 2 * 8 * 8 == sig.total


def test_arrival_order_skips_unit_trips():
    t = ewise_task("e", "o", ["i"], (1, 4, 4), dim_names=["n", "h", "w"])
    g = DataflowGraph("x")
    r = t.reads_from("i")[0]
    assert arrival_order(t, r) == (1, 2)  # n (trip 1) never varies


def test_enclosing_override_changes_counts():
    t = matmul_task("mm", "c", "a", "b", m=8, n=4, k=16)
    w = t.writes_to("c")[0]
    w.enclosing = ("m", "n")
    sig = access_sig(t, w)
    assert sig.total == 32 == sig.distinct
    assert not sig.repeats
