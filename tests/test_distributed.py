"""Sharding rules, HLO analysis, multi-device paths (subprocess: the
device count must be fixed before jax initializes)."""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import sanitize_spec
from repro.launch import hlo_analysis as ha

SRC = str(Path(__file__).resolve().parents[1] / "src")


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_use_mesh_shim_is_context_manager():
    """The version-compat shim must be enterable on whatever JAX is
    installed (jax.set_mesh does not exist everywhere)."""
    from repro.distributed.sharding import use_mesh
    mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
    with use_mesh(mesh):
        x = jnp.ones((4,))
        assert float(x.sum()) == 4.0


def test_sanitize_spec_drops_undivisible_when_lenient():
    mesh = FakeMesh({"data": 16, "model": 16})
    s = lambda *a, **k: sanitize_spec(*a, strict=False, **k)  # noqa: E731
    assert s(P("data"), (1,), mesh) == P(None)
    assert s(P("data", "model"), (32, 7), mesh) == P("data", None)
    assert s(P(("pod", "data"),), (32,),
             FakeMesh({"pod": 2, "data": 16})) == P(("pod", "data"))
    assert s(P(("pod", "data"),), (2,),
             FakeMesh({"pod": 2, "data": 16})) == P("pod")


def test_sanitize_spec_strict_rejects_undivisible():
    from repro.distributed.sharding import ShardingSpecError
    mesh = FakeMesh({"data": 16, "model": 16})
    with pytest.raises(ShardingSpecError, match="does not divide"):
        sanitize_spec(P("data", "model"), (32, 7), mesh)
    with pytest.raises(ShardingSpecError, match="only has axes"):
        sanitize_spec(P("pod"), (32,), mesh)
    # a clean spec passes through untouched
    assert sanitize_spec(P("data", "model"), (32, 32), mesh) \
        == P("data", "model")


def test_param_specs_cover_all_archs():
    mesh = FakeMesh({"data": 16, "model": 16})
    from repro.distributed.sharding import param_specs
    from repro.models import transformer as tf
    for arch in ("gemma-7b", "mixtral-8x22b", "mamba2-780m",
                 "recurrentgemma-9b", "whisper-large-v3"):
        cfg = get_config(arch)
        shapes = tf.param_shapes(cfg)
        specs = param_specs(shapes, mesh, cfg)
        import math
        flat_shapes = jax.tree.leaves(shapes)
        flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
        # every *large* tensor (>= 1M elements) must be sharded on >= 1 axis
        for leaf, spec in zip(flat_shapes, flat_specs):
            if math.prod(leaf.shape) >= 1_000_000:
                assert any(e is not None for e in spec), \
                    f"{arch}: unsharded large leaf {leaf.shape} {spec}"


# --------------------------------------------------------------------------
# HLO walker
# --------------------------------------------------------------------------


def test_hlo_walker_counts_scan_trips():
    def f(w, x):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=8)
        return jnp.sum(h)

    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((32, 128), jnp.float32)
    cost = ha.analyze(jax.jit(f).lower(w, x).compile().as_text())
    expect = 8 * 2 * 32 * 128 * 128
    assert abs(cost.flops - expect) / expect < 0.01


def test_hlo_walker_nested_and_grad():
    def f(w, x):
        def outer(h, _):
            def inner(g, _):
                return g @ w, None
            g, _ = jax.lax.scan(inner, h, None, length=4)
            return g, None
        h, _ = jax.lax.scan(outer, x, None, length=8)
        return jnp.sum(h)

    w = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    x = jax.ShapeDtypeStruct((16, 64), jnp.float32)
    fwd = ha.analyze(jax.jit(f).lower(w, x).compile().as_text())
    assert abs(fwd.flops - 32 * 2 * 16 * 64 * 64) / fwd.flops < 0.01
    bwd = ha.analyze(jax.jit(jax.grad(f)).lower(w, x).compile().as_text())
    assert bwd.flops >= 2.5 * fwd.flops          # fwd + 2 bwd matmuls


# --------------------------------------------------------------------------
# multi-device (subprocess with 8 host devices)
# --------------------------------------------------------------------------

MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import PartitionSpec as P
    import sys
    sys.path.insert(0, {src!r})

    out = {{}}

    # 1) jitted sharded train step on a 4x2 debug mesh
    from repro.launch.mesh import make_debug_mesh
    from repro.configs import get_config
    from repro.distributed.sharding import use_mesh
    from repro.models import transformer as tf, make_batch
    from repro.training.train_loop import jit_train_step
    from repro.training.optimizer import adamw_init, OptConfig

    mesh = make_debug_mesh((4, 2), ("data", "model"))
    cfg = get_config("gpt2-medium").smoke()
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    batch = make_batch(cfg, batch=4, seq=64, kind="train")
    step = jit_train_step(cfg, mesh, params, batch,
                          OptConfig(lr=1e-3, warmup_steps=1, total_steps=4))
    with use_mesh(mesh):
        for _ in range(3):
            params, opt, metrics = step(params, opt, batch)
    out["train_loss"] = float(metrics["loss"])

    # 2) pipeline executor vs serial reference on a 4-stage mesh
    from repro.core.pipeline import pipeline_fn, reference_serial, PipelineSchedule
    pmesh = make_debug_mesh((4,), ("stage",))
    D = 16
    def stage(p, x):
        return jnp.tanh(x @ p["w"])
    fns = [stage] * 4
    key = jax.random.PRNGKey(1)
    pstack = {{"w": jax.random.normal(key, (4, D, D)) * 0.5}}
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 4, D))  # (nmb, mb, D)
    y_pipe = pipeline_fn(fns, pmesh)(pstack, x)
    y_ref = reference_serial(fns, pstack, x)
    out["pipe_err"] = float(jnp.abs(y_pipe - y_ref).max())
    out["bubble"] = PipelineSchedule(4, 8).bubble_fraction

    # 3) compressed all-reduce under shard_map matches plain mean-free sum
    from repro.distributed import compression
    from repro.distributed.sharding import shard_map
    cmesh = make_debug_mesh((8,), ("data",))
    g_global = jax.random.normal(jax.random.PRNGKey(3), (8, 64)) * 1e-2
    def worker(g):
        grads = {{"g": g[0]}}
        st = {{}}
        red, st = compression.compressed_allreduce(grads, st, ("data",))
        return red["g"][None]
    red = jax.jit(shard_map(worker, mesh=cmesh, in_specs=P("data"),
                            out_specs=P("data"), check_vma=False))(g_global)
    want = jnp.sum(g_global, axis=0)
    err = jnp.abs(red[0] - want).max() / (jnp.abs(want).max() + 1e-9)
    out["allreduce_rel_err"] = float(err)

    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def multidev_results():
    script = MULTIDEV.format(src=SRC)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_sharded_train_step(multidev_results):
    assert np.isfinite(multidev_results["train_loss"])


def test_pipeline_executor_matches_serial(multidev_results):
    assert multidev_results["pipe_err"] < 1e-5
    assert 0 < multidev_results["bubble"] < 0.5


def test_compressed_allreduce(multidev_results):
    assert multidev_results["allreduce_rel_err"] < 0.02
