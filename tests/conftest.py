import os
import sys

# Tests see the default single CPU device (the dry-run sets its own flags
# in a separate process).  Keep compilation single-threaded and quiet.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
