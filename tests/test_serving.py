"""Serving-runtime battery (ISSUE 8): dynamic-batching window semantics,
cache-accounted single batched compile, bit-identical batched numerics,
queue-full backpressure, zero-downtime hot-swap under in-flight load,
worker-crash respawn/retry, and the deprecation shims.

Synchronization policy: every wait in here is event-based —
``pause``/``resume``/``flush``/``ServeFuture.result(timeout)`` — never a
sleep.  ``pause()`` + N ``submit()`` + ``resume()`` is the deterministic
way to place N requests inside one batching window.
"""

from __future__ import annotations

import importlib
import os
import pathlib
import warnings

import numpy as np
import pytest

import repro.api as codo
from repro.core.cache import CompileCache
from repro.kernels import register_all
from repro.serving import (QueueFullError, ServeConfig, ServeError,
                           ServingRuntime)

register_all()

TIMEOUT = 300        # generous per-future bound; waits are event-based


def _model(x):
    h = codo.F.fc(x, 24, relu=True)
    return codo.F.fc(h, 12)


def _bound_program(cache, scale=1.0, shape=(8, 16)):
    """A compiled tiny MLP with deterministic bound weights (``scale``
    makes two observably different model generations)."""
    p = codo.compile(_model, shape, cache=cache)
    w = {b.name: scale * np.asarray(
        codo.F.weight_init(b.shape, b.dtype)) for b in p.graph.weights()}
    p.bind(**w)
    return p


def _inputs(n, shape=(8, 16), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.standard_normal(shape).astype("float32") for _ in range(n)]


# --------------------------------------------------------------------------
# Dynamic batching
# --------------------------------------------------------------------------


def test_window_coalesces_to_exactly_one_batched_compile():
    cache = CompileCache()
    p = _bound_program(cache)
    with ServingRuntime(ServeConfig(batch_window_ms=500, max_batch=4),
                        cache=cache) as rt:
        rt.add_model("m", p)
        xs = _inputs(4)

        misses0 = cache.stats.misses
        rt.pause()
        futs = [rt.submit("m", x=x) for x in xs]
        rt.resume()
        outs = [f.result(timeout=TIMEOUT) for f in futs]
        assert len(outs) == 4
        # One dispatch group, all four coalesced, ONE compile for the
        # leading-batch-dim design (cache accounting: exactly one miss).
        assert rt.stats.batches == 1
        assert rt.stats.batched_requests == 4
        assert rt.stats.fallback_requests == 0
        assert cache.stats.misses - misses0 == 1

        # A second identical window re-uses the batched program: zero new
        # compiles anywhere.
        misses1 = cache.stats.misses
        rt.pause()
        futs = [rt.submit("m", x=x) for x in xs]
        rt.resume()
        [f.result(timeout=TIMEOUT) for f in futs]
        assert rt.stats.batches == 2
        assert cache.stats.misses == misses1


def test_batched_results_bit_identical_to_sequential():
    cache = CompileCache()
    p = _bound_program(cache)
    name = p.output_names[0]
    xs = _inputs(6, seed=3)
    want = [np.asarray(p(x)) for x in xs]
    with ServingRuntime(ServeConfig(batch_window_ms=500, max_batch=6),
                        cache=cache) as rt:
        rt.add_model("m", p)
        rt.pause()
        futs = [rt.submit("m", x=x) for x in xs]
        rt.resume()
        outs = [f.result(timeout=TIMEOUT) for f in futs]
    assert rt.stats.batched_requests == 6
    for got, ref in zip(outs, want):
        np.testing.assert_array_equal(got[name], ref)   # bit-identical


def test_mixed_shape_traffic_never_cross_batches():
    cache = CompileCache()
    p_a = _bound_program(cache, shape=(8, 16))
    p_b = _bound_program(cache, shape=(4, 16))
    name = p_a.output_names[0]
    xa, xb = _inputs(2, (8, 16), seed=1), _inputs(2, (4, 16), seed=2)
    with ServingRuntime(ServeConfig(batch_window_ms=500, max_batch=8),
                        cache=cache) as rt:
        rt.add_model("a", p_a)
        rt.add_model("b", p_b)
        rt.pause()
        futs = [rt.submit("a", x=xa[0]), rt.submit("b", x=xb[0]),
                rt.submit("a", x=xa[1]), rt.submit("b", x=xb[1])]
        rt.resume()
        outs = [f.result(timeout=TIMEOUT) for f in futs]
    # Two dispatch groups — one per model — and every batched program
    # that exists has each model's own shape (no cross-batching).
    assert rt.stats.batches == 2
    for handle, prog in ((rt._models["a"], p_a), (rt._models["b"], p_b)):
        for size, bp in handle.batched.items():
            batched_in = bp.graph.buffers[prog.input_names[0]]
            orig_in = prog.graph.buffers[prog.input_names[0]]
            assert tuple(batched_in.shape) == (size, *orig_in.shape)
    np.testing.assert_array_equal(outs[0][name], np.asarray(p_a(xa[0])))
    np.testing.assert_array_equal(outs[1][name], np.asarray(p_b(xb[0])))
    np.testing.assert_array_equal(outs[2][name], np.asarray(p_a(xa[1])))
    np.testing.assert_array_equal(outs[3][name], np.asarray(p_b(xb[1])))


def test_non_batchable_design_falls_back_per_request():
    from repro.models import dataflow_models as dm
    cache = CompileCache()
    g = dm.residual_block(1, 8, 12)         # conv ops: not batchable
    p = codo.compile(g, cache=cache)
    from repro.core.frontend import batch_blockers
    assert batch_blockers(p.source)         # precondition of this test
    env = dm.random_inputs(g, seed=0)
    want = p.lower(jit=True)(p.make_env(**env))
    with ServingRuntime(ServeConfig(batch_window_ms=500, max_batch=3),
                        cache=cache) as rt:
        rt.add_model("m", p, warm=False)
        rt.pause()
        futs = [rt.submit("m", **env) for _ in range(3)]
        rt.resume()
        outs = [f.result(timeout=TIMEOUT) for f in futs]
    assert rt.stats.fallback_requests == 3
    assert rt.stats.batched_requests == 0
    for out in outs:
        for k in want:
            np.testing.assert_array_equal(out[k], np.asarray(want[k]))


# --------------------------------------------------------------------------
# Backpressure + request-path errors
# --------------------------------------------------------------------------


def test_bounded_queue_backpressure():
    cache = CompileCache()
    p = _bound_program(cache)
    with ServingRuntime(ServeConfig(batch_window_ms=500, max_batch=4,
                                    max_queue=4), cache=cache) as rt:
        rt.add_model("m", p)
        rt.pause()                          # queue fills deterministically
        xs = _inputs(4)
        futs = [rt.submit("m", x=x) for x in xs]
        with pytest.raises(QueueFullError):
            rt.submit("m", x=xs[0])
        rt.resume()
        assert all(f.result(timeout=TIMEOUT) is not None for f in futs)
    assert rt.stats.completed == 4


def test_unknown_model_and_closed_runtime_raise():
    cache = CompileCache()
    p = _bound_program(cache)
    rt = ServingRuntime(ServeConfig(batch_window_ms=1), cache=cache)
    rt.add_model("m", p)
    with pytest.raises(KeyError):
        rt.submit("nope", x=_inputs(1)[0])
    rt.close()
    with pytest.raises(ServeError):
        rt.submit("m", x=_inputs(1)[0])


def test_execution_error_is_a_clean_response():
    cache = CompileCache()
    p = _bound_program(cache)
    with ServingRuntime(ServeConfig(batch_window_ms=1), cache=cache) as rt:
        rt.add_model("m", p)
        fut = rt.submit("m", wrong_name=_inputs(1)[0])
        with pytest.raises(ServeError, match="execution failed"):
            fut.result(timeout=TIMEOUT)
    assert rt.stats.failed == 1


# --------------------------------------------------------------------------
# Hot-swap
# --------------------------------------------------------------------------


def test_hot_swap_under_load_loses_zero_requests():
    cache = CompileCache()
    p_old = _bound_program(cache, scale=1.0)
    p_new = _bound_program(cache, scale=2.0)
    name = p_old.output_names[0]
    xs = _inputs(12, seed=7)
    want_old = [np.asarray(p_old(x)) for x in xs]
    want_new = [np.asarray(p_new(x)) for x in xs]
    with ServingRuntime(ServeConfig(batch_window_ms=500, max_batch=4),
                        cache=cache) as rt:
        rt.add_model("m", p_old)
        rt.pause()
        futs = [rt.submit("m", x=x) for x in xs]    # 3 windows queued
        rt.resume()
        # Swap while those requests are in flight/queued: the replacement
        # is warmed before the atomic flip; dispatched work drains on the
        # old design.
        rt.swap("m", p_new)
        outs = [f.result(timeout=TIMEOUT) for f in futs]
    assert rt.stats.swaps == 1
    assert rt.stats.completed == len(xs)            # zero requests lost
    assert rt.stats.failed == 0
    from_old = from_new = 0
    for got, old, new in zip(outs, want_old, want_new):
        # Every response is *exactly* one generation's answer — a swap
        # mid-stream never yields a mixed or torn result.
        if np.array_equal(got[name], old):
            from_old += 1
        elif np.array_equal(got[name], new):
            from_new += 1
        else:
            raise AssertionError("response matches neither generation")
    assert from_old + from_new == len(xs)


def test_post_swap_requests_serve_the_new_design():
    cache = CompileCache()
    p_old = _bound_program(cache, scale=1.0)
    p_new = _bound_program(cache, scale=3.0)
    name = p_old.output_names[0]
    x = _inputs(1, seed=9)[0]
    with ServingRuntime(ServeConfig(batch_window_ms=1), cache=cache) as rt:
        rt.add_model("m", p_old)
        np.testing.assert_array_equal(
            rt.submit("m", x=x).result(timeout=TIMEOUT)[name],
            np.asarray(p_old(x)))
        rt.swap("m", p_new)
        np.testing.assert_array_equal(
            rt.submit("m", x=x).result(timeout=TIMEOUT)[name],
            np.asarray(p_new(x)))
    assert rt.stats.failed == 0


def test_swap_unknown_model_raises():
    cache = CompileCache()
    with ServingRuntime(ServeConfig(batch_window_ms=1), cache=cache) as rt:
        with pytest.raises(KeyError):
            rt.swap("ghost", _bound_program(cache))


# --------------------------------------------------------------------------
# Process workers: shared disk cache, crash respawn, bounded retries
# --------------------------------------------------------------------------


def _export_served(tmp_path, cache, scale=1.0):
    p = _bound_program(cache, scale=scale)
    path = tmp_path / f"served_{scale}.json"
    p.export(str(path), weights=True)       # self-contained v1.3 artifact
    return p, str(path)


def test_worker_pool_serves_batched_and_shares_disk_cache(tmp_path):
    cache = CompileCache(disk_dir=tmp_path / "cache")
    p, path = _export_served(tmp_path, cache)
    name = p.output_names[0]
    xs = _inputs(4, seed=11)
    want = [np.asarray(p(x)) for x in xs]
    before = set((tmp_path / "cache").glob("*.pkl"))
    with ServingRuntime(ServeConfig(batch_window_ms=200, max_batch=4,
                                    workers=1), cache=cache) as rt:
        rt.add_model("m", path)
        rt.pause()
        futs = [rt.submit("m", x=x) for x in xs]
        rt.resume()
        outs = [f.result(timeout=TIMEOUT) for f in futs]
    assert rt.stats.batched_requests == 4
    for got, ref in zip(outs, want):
        np.testing.assert_array_equal(got[name], ref)
    # The worker compiled the batched design through the *shared* disk
    # cache: the parent's cache dir gained entries it can now hit.
    assert set((tmp_path / "cache").glob("*.pkl")) > before


def test_worker_crash_respawns_and_retries_request(tmp_path, monkeypatch):
    marker = tmp_path / "crash.marker"
    marker.write_text("armed")
    monkeypatch.setenv("CODO_SERVE_FAULT", f"crash_once:{marker}")
    cache = CompileCache(disk_dir=tmp_path / "cache")
    p, path = _export_served(tmp_path, cache)
    name = p.output_names[0]
    x = _inputs(1, seed=13)[0]
    with ServingRuntime(ServeConfig(batch_window_ms=1, workers=1,
                                    max_retries=2), cache=cache) as rt:
        rt.add_model("m", path)
        fut = rt.submit("m", x=x)
        out = fut.result(timeout=TIMEOUT)   # survives the crash
    np.testing.assert_array_equal(out[name], np.asarray(p(x)))
    assert not marker.exists()              # the fault actually fired
    assert rt.stats.respawns >= 1           # pool was rebuilt
    assert rt.stats.retries >= 1            # the request was re-queued
    assert rt.stats.completed == 1


def test_worker_crash_bounded_retries_then_clean_error(tmp_path,
                                                       monkeypatch):
    monkeypatch.setenv("CODO_SERVE_FAULT", "crash")    # dies every time
    cache = CompileCache(disk_dir=tmp_path / "cache")
    _p, path = _export_served(tmp_path, cache)
    x = _inputs(1, seed=17)[0]
    rt = ServingRuntime(ServeConfig(batch_window_ms=1, workers=1,
                                    max_retries=1), cache=cache)
    try:
        rt.add_model("m", path)
        fut = rt.submit("m", x=x)
        with pytest.raises(ServeError, match="worker crashes"):
            fut.result(timeout=TIMEOUT)
        assert rt.stats.failed == 1
        assert rt.stats.retries == 1        # bounded: exactly max_retries
    finally:
        monkeypatch.setenv("CODO_SERVE_FAULT", "")
        rt.close()


# --------------------------------------------------------------------------
# Deprecation shims (the launch/serve.py vs serving/serve.py split fix)
# --------------------------------------------------------------------------


def test_launch_serve_shim_warns_and_delegates():
    import repro.launch.serve as shim
    import repro.serving.cli as cli
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shim = importlib.reload(shim)
    assert any(issubclass(w.category, DeprecationWarning)
               and "repro.serving.cli" in str(w.message) for w in caught)
    assert shim.main is cli.main
    assert shim.InputError is cli.InputError
    assert shim.load_input_env is cli.load_input_env
    assert shim.serve_artifact is cli.serve_artifact


def test_serving_serve_shim_warns_and_delegates():
    import repro.serving.generator as generator
    import repro.serving.serve as shim
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        shim = importlib.reload(shim)
    assert any(issubclass(w.category, DeprecationWarning)
               and "repro.serving.generator" in str(w.message)
               for w in caught)
    assert shim.Generator is generator.Generator
    assert shim.Request is generator.Request
    assert shim.build_serve_step is generator.build_serve_step
    assert shim.build_prefill_step is generator.build_prefill_step


# --------------------------------------------------------------------------
# Config knobs
# --------------------------------------------------------------------------


def test_serve_config_reads_env_knobs(monkeypatch):
    monkeypatch.setenv("CODO_SERVE_BATCH_WINDOW_MS", "7.5")
    monkeypatch.setenv("CODO_SERVE_MAX_QUEUE", "33")
    monkeypatch.setenv("CODO_SERVE_WORKERS", "2")
    cfg = ServeConfig.from_env()
    assert cfg.batch_window_ms == 7.5
    assert cfg.max_queue == 33
    assert cfg.workers == 2
    # overrides beat env; garbage falls back to defaults
    assert ServeConfig.from_env(workers=0).workers == 0
    monkeypatch.setenv("CODO_SERVE_MAX_QUEUE", "not-a-number")
    assert ServeConfig.from_env().max_queue == 256
