"""Fine-grained violation elimination (Figs. 5-6) unit tests."""

from repro.core import DataflowGraph, eliminate_fine, fine_violations, matmul_task, ewise_task
from repro.core.fine import generate_permutation, rewrite_reduction_write
from repro.core.patterns import (BROADCAST_REREAD, MULTI_WRITE,
                                 ORDER_MISMATCH, access_sig)


def _mm_chain():
    g = DataflowGraph("mm_chain")
    g.buffer("a", (8, 16), kind="input")
    g.buffer("b", (16, 8), kind="weight")
    g.buffer("c", (8, 8))
    g.buffer("o", (8, 8), kind="output")
    g.add_task(matmul_task("mm", "c", "a", "b", 8, 8, 16,
                           fn=lambda e: {"c": e["a"] @ e["b"]}))
    g.add_task(ewise_task("relu", "o", ["c"], (8, 8),
                          fn=lambda e: {"o": e["c"]}))
    return g


def test_reduction_rewriting_fixes_multiwrite():
    g = _mm_chain()
    kinds = {v.kind for v in fine_violations(g)}
    assert MULTI_WRITE in kinds
    rep = eliminate_fine(g)
    assert rep.reductions_rewritten
    assert MULTI_WRITE not in {v.kind for v in fine_violations(g)}
    mm = g.task("mm")
    # reduction dim moved innermost, write emitted once per element
    assert mm.loops[-1].var == "k"
    w = mm.writes_to("c")[0]
    assert w.enclosing == ("m", "n")
    assert mm.reduction_rewritten


def test_reduction_rewrite_idempotent():
    g = _mm_chain()
    mm = g.task("mm")
    assert rewrite_reduction_write(mm, "c")
    assert not rewrite_reduction_write(mm, "c")  # nothing left to hoist


def test_order_mismatch_permutation():
    """producer writes (i,j) row-major; consumer reads transposed order."""
    from repro.core.graph import Access, Loop, Task, idx

    g = DataflowGraph("perm")
    g.buffer("x", (8, 4), kind="input")
    g.buffer("m", (8, 4))
    g.buffer("o", (8, 4), kind="output")
    g.add_task(ewise_task("p", "m", ["x"], (8, 4), dim_names=["i", "j"],
                          fn=lambda e: {"m": e["x"]}))
    # consumer iterates (j, i) but reads m[i, j]
    c = Task("c", [Loop("j", 4), Loop("i", 8)],
             [Access("m", (idx("i"), idx("j")), False)],
             [Access("o", (idx("i"), idx("j")), True)],
             flops_per_iter=100.0,   # make consumer the reference loop
             fn=lambda e: {"o": e["m"]})
    g.add_task(c)
    kinds = {v.kind for v in fine_violations(g)}
    assert ORDER_MISMATCH in kinds
    rep = eliminate_fine(g)
    assert rep.permutations
    pm = rep.permutations[0]
    assert pm.target == "p" and pm.reference == "c"
    assert not fine_violations(g)
    # producer loop order now matches consumer arrival order (j outer)
    p = g.task("p")
    assert [l.var for l in p.loops] == ["j", "i"]


def test_broadcast_reread_cached():
    g = _mm_chain()
    # the lhs 'a' is an input (exempt); make it an intermediate to trigger
    g.buffers["a"].kind = "intermediate"
    g.buffer("a0", (8, 16), kind="input")
    g.add_task(ewise_task("ld", "a", ["a0"], (8, 16), dim_names=["m", "k"],
                          fn=lambda e: {"a": e["a0"]}))
    kinds = {v.kind for v in fine_violations(g)}
    assert BROADCAST_REREAD in kinds
    eliminate_fine(g)
    mm = g.task("mm")
    r = mm.reads_from("a")[0]
    assert r.enclosing is not None          # cached: read exactly once
    assert not access_sig(mm, r).repeats
