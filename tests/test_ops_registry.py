"""Declarative op-registry tests: OpSpec data plumbing, materialization,
registration, rename-vs-retarget equivalence, and pickling."""

import pickle

import numpy as np
import pytest

from repro.core import DataflowGraph, OpSpec, UnknownOpError, ewise_task
from repro.core.ops import materialize, register_op, registered_ops


def test_registry_covers_builder_vocabulary():
    need = {"identity", "dup", "fused", "pad2d", "conv2d", "relu", "gelu",
            "add", "vadd", "scale", "softmax", "matmul", "mv", "transpose",
            "maxpool2d", "mean", "reshape"}
    assert need <= set(registered_ops())


def test_unknown_kind_raises_eagerly():
    with pytest.raises(UnknownOpError, match="registered"):
        materialize(OpSpec("no-such-op", ("x",), ("o",)))


def test_materialize_basic_ops():
    x = np.arange(6.0, dtype=np.float32).reshape(2, 3)
    out = materialize(OpSpec("scale", ("x",), ("o",), {"s": 2.0}))({"x": x})
    np.testing.assert_allclose(np.asarray(out["o"]), x * 2.0)
    out = materialize(OpSpec("vadd", ("a", "b"), ("o",),
                             {"alpha": 2.0, "beta": 3.0}))({"a": x, "b": x})
    np.testing.assert_allclose(np.asarray(out["o"]), 5.0 * x)
    out = materialize(OpSpec("transpose", ("x",), ("o",)))({"x": x})
    assert np.asarray(out["o"]).shape == (3, 2)


def test_dup_and_fused_composition():
    x = np.ones((4,), np.float32)
    dup = materialize(OpSpec("dup", ("x",), ("a", "b")))({"x": x})
    assert set(dup) == {"a", "b"}
    fused = OpSpec("fused", parts=(
        OpSpec("scale", ("x",), ("y",), {"s": 3.0}),
        OpSpec("add", ("y", "x"), ("o",)),
    ))
    out = materialize(fused)({"x": x})
    np.testing.assert_allclose(np.asarray(out["o"]), 4.0 * np.ones(4))
    assert "y" in out  # staged intermediate is surfaced like the closure did


def test_renamed_is_pure_and_recursive():
    spec = OpSpec("fused", parts=(
        OpSpec("scale", ("x",), ("y",), {"s": 3.0}),
        OpSpec("add", ("y", "x"), ("o",)),
    ))
    r = spec.renamed({"x": "x2", "o": "o2"})
    assert spec.parts[0].ins == ("x",), "rename must not mutate the original"
    assert r.parts[0].ins == ("x2",) and r.parts[1].outs == ("o2",)
    out = materialize(r)({"x2": np.ones(3, np.float32)})
    np.testing.assert_allclose(np.asarray(out["o2"]), 4.0 * np.ones(3))


def test_signature_covers_attrs_and_parts():
    a = OpSpec("scale", ("x",), ("o",), {"s": 1.5})
    b = OpSpec("scale", ("x",), ("o",), {"s": 2.5})
    assert a.signature() != b.signature()
    assert a.signature() == OpSpec("scale", ("x",), ("o",), {"s": 1.5}).signature()
    f1 = OpSpec("fused", parts=(a,))
    f2 = OpSpec("fused", parts=(b,))
    assert f1.signature() != f2.signature()


def test_register_op_and_task_derivation():
    @register_op("test-axpy")
    def _axpy(spec, env):
        return {spec.outs[0]: spec.attrs["a"] * env[spec.ins[0]] + env[spec.ins[1]]}

    t = ewise_task("t", "o", ["x", "y"], (3,),
                   spec=OpSpec("test-axpy", ("x", "y"), ("o",), {"a": 2.0}))
    assert not t.fn_is_closure
    env = {"x": np.ones(3), "y": np.zeros(3)}
    np.testing.assert_allclose(t.fn(env)["o"], 2.0 * np.ones(3))
    # closure override wins over the spec
    t.fn = lambda e: {"o": e["x"] * 0}
    assert t.fn_is_closure
    np.testing.assert_allclose(t.fn(env)["o"], np.zeros(3))
    t.fn = None
    np.testing.assert_allclose(t.fn(env)["o"], 2.0 * np.ones(3))


def test_spec_task_pickles_and_reexecutes():
    t = ewise_task("t", "o", ["x"], (4,),
                   spec=OpSpec("scale", ("x",), ("o",), {"s": 3.0}))
    t2 = pickle.loads(pickle.dumps(t))
    np.testing.assert_allclose(t2.fn({"x": np.ones(4)})["o"], 3.0 * np.ones(4))


def test_graph_execute_via_specs_without_closures():
    g = DataflowGraph("g")
    g.buffer("x", (4,), kind="input")
    g.buffer("h", (4,))
    g.buffer("o", (4,), kind="output")
    g.add_task(ewise_task("s", "h", ["x"], (4,),
                          spec=OpSpec("scale", ("x",), ("h",), {"s": 2.0})))
    g.add_task(ewise_task("a", "o", ["h", "x"], (4,),
                          spec=OpSpec("add", ("h", "x"), ("o",))))
    out = g.execute({"x": np.ones(4, np.float32)})
    np.testing.assert_allclose(np.asarray(out["o"]), 3.0 * np.ones(4))


def test_task_retarget_spec_vs_closure():
    from repro.core import retarget_fn

    spec_t = ewise_task("s", "o", ["x"], (4,),
                        spec=OpSpec("scale", ("x",), ("o",), {"s": 2.0}))
    spec_t.retarget({"x": "x2"})
    assert spec_t.spec.ins == ("x2",)
    np.testing.assert_allclose(spec_t.fn({"x2": np.ones(4)})["o"], 2 * np.ones(4))

    clos_t = ewise_task("c", "o", ["x"], (4,), fn=lambda e: {"o": e["x"] * 2})
    clos_t.retarget({"x": "x2", "o": "o2"})
    out = clos_t.fn({"x2": np.ones(4)})
    np.testing.assert_allclose(out["o2"], 2 * np.ones(4))
    assert retarget_fn is not None  # legacy shim stays exported


def test_reregistration_invalidates_memoized_lowerings():
    """register_op re-registration bumps the ops epoch, so lower()'s memo
    must rebuild instead of serving programs built from the old impl."""
    from repro.core import clear_lower_cache, codo_opt, lower
    from repro.core.ops import op_impl

    kind = "test-epoch-op"

    @register_op(kind)
    def _v1(spec, env):
        return {spec.outs[0]: env[spec.ins[0]] * 2.0}

    def build():
        g = DataflowGraph("epoch_g")
        g.buffer("x", (4,), kind="input")
        g.buffer("o", (4,), kind="output")
        g.add_task(ewise_task("t", "o", ["x"], (4,),
                              spec=OpSpec(kind, ("x",), ("o",))))
        return g

    clear_lower_cache()
    env = {"x": np.ones(4, np.float32)}
    out1 = lower(codo_opt(build(), cache=None), jit=False)(env)
    np.testing.assert_allclose(out1["o"], 2.0 * np.ones(4))

    @register_op(kind)
    def _v2(spec, env):
        return {spec.outs[0]: env[spec.ins[0]] * 5.0}

    assert op_impl(kind) is _v2
    out2 = lower(codo_opt(build(), cache=None), jit=False)(env)
    np.testing.assert_allclose(out2["o"], 5.0 * np.ones(4),
                               err_msg="stale memoized lowering served")


def test_coarse_rewrites_stay_declarative():
    """Duplicators and fused producers emitted by the coarse pass must be
    spec-carrying when the inputs are (no closures sneak back in)."""
    from repro.core import codo_opt
    from repro.models import dataflow_models as dm

    c = codo_opt(dm.residual_block(1, 8, 12), cache=None)
    dup = [t for t in c.graph.tasks if "coarse-duplicator" in t.tags]
    assert dup and all(t.spec is not None and t.spec.kind == "dup" for t in dup)
    assert all(not t.fn_is_closure for t in c.graph.tasks)
    # Task objects of the compiled result pickle as-is
    pickle.dumps(c.graph.tasks)
