"""Per-architecture smoke tests: reduced same-family config, one forward/
train step + one decode step on CPU, asserting shapes and finiteness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as M
from repro.configs import CONFIGS, SHAPES, get_config

ARCHS = sorted(CONFIGS)


@pytest.fixture(scope="module")
def smoke_state():
    cache = {}

    def get(name):
        if name not in cache:
            cfg = get_config(name).smoke()
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            cache[name] = (cfg, params)
        return cache[name]

    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(smoke_state, arch):
    cfg, params = smoke_state(arch)
    batch = M.make_batch(cfg, batch=2, seq=64, kind="train")
    loss = M.loss_fn(params, batch, cfg, remat=False)
    assert loss.shape == ()
    assert np.isfinite(float(loss))
    # gradient flows to every leaf
    grads = jax.grad(lambda p: M.loss_fn(p, batch, cfg, remat=False))(params)
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes(smoke_state, arch):
    cfg, params = smoke_state(arch)
    batch = M.make_batch(cfg, batch=2, seq=64, kind="prefill")
    logits = M.forward(params, batch, cfg, remat=False)
    s_text = 64 - cfg.n_patches if cfg.n_patches else 64
    assert logits.shape == (2, s_text, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_smoke(smoke_state, arch):
    cfg, params = smoke_state(arch)
    cache = M.init_cache(cfg, batch=2, seq_len=64)
    toks = jnp.ones((2,), jnp.int32)
    for _ in range(3):
        logits, cache = M.decode_step(params, toks, cache, cfg)
        toks = jnp.argmax(logits, -1).astype(jnp.int32) % cfg.vocab
    assert logits.shape == (2, cfg.padded_vocab)
    assert int(cache["pos"]) == 3
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("arch", ["starcoder2-15b", "recurrentgemma-9b",
                                  "mixtral-8x22b"])
def test_windowed_cache_is_ring(arch):
    """Windowed archs keep a bounded cache regardless of seq_len."""
    cfg = get_config(arch)
    C = M.cache_len_for(cfg, 524_288)
    bound = cfg.local_window if len(cfg.block_pattern) > 1 else cfg.window
    assert C == bound


def test_ssm_cache_constant():
    cfg = get_config("mamba2-780m")
    sm = cfg.smoke()
    c1 = M.init_cache(sm, batch=2, seq_len=64)
    c2 = M.init_cache(sm, batch=2, seq_len=4096)
    sz = lambda c: sum(x.size for x in jax.tree.leaves(c))
    assert sz(c1) == sz(c2)                 # O(1) state in sequence length


def test_long500k_runnable_flags():
    runnable = {a for a, c in CONFIGS.items()
                if c.runnable(SHAPES["long_500k"])[0]}
    assert runnable == {"starcoder2-15b", "recurrentgemma-9b",
                        "mixtral-8x22b", "mamba2-780m"}


def test_decode_matches_forward_dense():
    """Teacher-forced decode reproduces the training forward logits."""
    cfg = get_config("gpt2-medium").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    batch = M.make_batch(cfg, batch=2, seq=8, kind="prefill", seed=3)
    full = M.forward(params, batch, cfg, remat=False)
    cache = M.init_cache(cfg, batch=2, seq_len=8)
    toks = batch["tokens"]
    outs = []
    for t in range(8):
        logits, cache = M.decode_step(params, toks[:, t], cache, cfg)
        outs.append(logits)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_moe_routes_to_multiple_experts():
    cfg = get_config("mixtral-8x22b").smoke()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = M.make_batch(cfg, batch=2, seq=64, kind="train", seed=1)
    # different tokens should produce different expert mixes -> nonzero var
    l1 = float(M.loss_fn(params, batch, cfg, remat=False))
    batch2 = M.make_batch(cfg, batch=2, seq=64, kind="train", seed=2)
    l2 = float(M.loss_fn(params, batch2, cfg, remat=False))
    assert l1 != l2
