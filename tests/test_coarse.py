"""Coarse-grained violation elimination (Alg. 1 / Fig. 4) unit tests."""

import jax.numpy as jnp
import numpy as np

from repro.core import DataflowGraph, coarse_violations, eliminate_coarse, ewise_task
from repro.core.patterns import MPMC, MPSC, SPMC


def _spmc_graph():
    """one producer, two consumers of buffer m (Fig. 4a bypass)."""
    g = DataflowGraph("spmc")
    g.buffer("x", (8,), kind="input")
    g.buffer("m", (8,))
    g.buffer("o1", (8,), kind="output")
    g.buffer("o2", (8,), kind="output")
    g.add_task(ewise_task("p", "m", ["x"], (8,), fn=lambda e: {"m": e["x"] + 1}))
    g.add_task(ewise_task("c1", "o1", ["m"], (8,), fn=lambda e: {"o1": e["m"] * 2}))
    g.add_task(ewise_task("c2", "o2", ["m"], (8,), fn=lambda e: {"o2": e["m"] * 3}))
    return g


def test_spmc_duplicator():
    g = _spmc_graph()
    vs = coarse_violations(g)
    assert [v.kind for v in vs] == [SPMC]
    rep = eliminate_coarse(g)
    assert not coarse_violations(g)
    assert rep.duplicators_inserted == ["dup_m"]
    # numeric equivalence after rewiring
    out = g.execute({"x": jnp.arange(8.0)})
    assert np.allclose(out["o1"], (np.arange(8) + 1) * 2)
    assert np.allclose(out["o2"], (np.arange(8) + 1) * 3)


def _mpsc_graph():
    """two producers writing disjoint halves of buffer m (init/pad pair)."""
    g = DataflowGraph("mpsc")
    g.buffer("x", (8,), kind="input")
    g.buffer("m", (8,))
    g.buffer("o", (8,), kind="output")

    def w1(env):
        return {"m": jnp.zeros(8).at[:4].set(env["x"][:4])}

    def w2(env):
        # merge semantics: earlier partial results are staged in scope and
        # folded into the last write (the fused node runs w1 then w2)
        return {"m": env["m"].at[4:].set(env["x"][4:] * 5)}

    g.add_task(ewise_task("init", "m", ["x"], (8,), fn=w1))
    g.add_task(ewise_task("fill", "m", ["x"], (8,), fn=w2))
    g.add_task(ewise_task("c", "o", ["m"], (8,), fn=lambda e: {"o": e["m"] + 1}))
    return g


def test_mpsc_fusion():
    g = _mpsc_graph()
    vs = coarse_violations(g)
    assert vs and vs[0].kind == MPSC
    rep = eliminate_coarse(g)
    assert not coarse_violations(g)
    assert rep.fusions or rep.merges
    out = g.execute({"x": jnp.arange(8.0)})
    want = np.concatenate([np.arange(4), np.arange(4, 8) * 5]) + 1
    assert np.allclose(out["o"], want)


def test_mpmc_resolves_to_clean_graph():
    g = DataflowGraph("mpmc")
    g.buffer("x", (8,), kind="input")
    g.buffer("m", (8,))
    g.buffer("o1", (8,), kind="output")
    g.buffer("o2", (8,), kind="output")
    g.add_task(ewise_task("p1", "m", ["x"], (8,),
                          fn=lambda e: {"m": e["x"] + 1}))
    t2 = ewise_task("p2", "m", ["x"], (8,), fn=lambda e: {"m": e["m"] * 2})
    t2.reads.append(t2.writes[0].copy())
    t2.reads[-1].is_write = False
    g.add_task(t2)
    g.add_task(ewise_task("c1", "o1", ["m"], (8,),
                          fn=lambda e: {"o1": e["m"] + 10}))
    g.add_task(ewise_task("c2", "o2", ["m"], (8,),
                          fn=lambda e: {"o2": e["m"] + 20}))
    vs = coarse_violations(g)
    assert vs[0].kind == MPMC
    eliminate_coarse(g)
    assert not coarse_violations(g)
    out = g.execute({"x": jnp.arange(8.0)})
    want = (np.arange(8) + 1) * 2
    assert np.allclose(out["o1"], want + 10)
    assert np.allclose(out["o2"], want + 20)
