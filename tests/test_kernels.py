"""Per-kernel shape/dtype sweeps vs pure-jnp oracles (interpret mode)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flashattn import attention_ref, flash_attn
from repro.kernels.matmul import matmul_ref, mm
from repro.kernels.rglru import rglru, rglru_ref
from repro.kernels.ssd import ssd_chunk_scan_ref, ssd_states
from repro.kernels.streamfuse import pad_conv_relu, pad_conv_relu_ref

RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("B,Hq,Hkv,S,hd", [
    (1, 2, 2, 128, 64), (2, 4, 2, 256, 64), (1, 8, 1, 256, 128),
    (2, 2, 2, 384, 32),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flashattn_sweep(B, Hq, Hkv, S, hd, causal, window, dtype):
    q = jnp.asarray(RNG.standard_normal((B, Hq, S, hd)), dtype)
    k = jnp.asarray(RNG.standard_normal((B, Hkv, S, hd)), dtype)
    v = jnp.asarray(RNG.standard_normal((B, Hkv, S, hd)), dtype)
    got = flash_attn(q, k, v, causal=causal, window=window)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.slow
@pytest.mark.parametrize("M,N,K", [(128, 128, 128), (256, 128, 384),
                                   (128, 384, 256), (512, 128, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_sweep(M, N, K, dtype):
    a = jnp.asarray(RNG.standard_normal((M, K)), dtype)
    b = jnp.asarray(RNG.standard_normal((K, N)), dtype)
    got = mm(a, b)
    want = matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2 if dtype == jnp.bfloat16 else 1e-3,
                               atol=5e-1 if dtype == jnp.bfloat16 else 1e-3)


@pytest.mark.parametrize("N,C,H,W,CO,K", [
    (1, 3, 16, 16, 8, 3), (2, 4, 8, 12, 4, 5), (1, 8, 24, 24, 16, 3),
])
def test_streamfuse_sweep(N, C, H, W, CO, K):
    # the real Pallas body (interpret mode) — pad_conv_relu's backend
    # dispatch would use the jnp reference on CPU hosts and test nothing
    from repro.kernels.streamfuse import fused_pad_conv_relu
    x = jnp.asarray(RNG.standard_normal((N, C, H, W)), jnp.float32)
    w = jnp.asarray(RNG.standard_normal((CO, C, K, K)) * 0.2, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(fused_pad_conv_relu(x, w, interpret=True)),
        np.asarray(pad_conv_relu_ref(x, w)), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(pad_conv_relu(x, w)),
                               np.asarray(pad_conv_relu_ref(x, w)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("B,S,D,chunk", [(2, 256, 64, 128), (1, 128, 128, 32),
                                         (3, 64, 32, 64)])
def test_rglru_sweep(B, S, D, chunk):
    a = jnp.asarray(RNG.uniform(0.5, 0.999, (B, S, D)), jnp.float32)
    b = jnp.asarray(RNG.standard_normal((B, S, D)) * 0.1, jnp.float32)
    np.testing.assert_allclose(np.asarray(rglru(a, b, chunk=chunk)),
                               np.asarray(rglru_ref(a, b)),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("nc,BH,P,N", [(8, 4, 16, 32), (4, 8, 8, 16),
                                       (16, 2, 32, 8)])
def test_ssd_sweep(nc, BH, P, N):
    st = jnp.asarray(RNG.standard_normal((nc, BH, P, N)) * 0.1, jnp.float32)
    dec = jnp.asarray(RNG.uniform(0.5, 0.99, (nc, BH, 1, 1)), jnp.float32)
    np.testing.assert_allclose(np.asarray(ssd_states(st, dec)),
                               np.asarray(ssd_chunk_scan_ref(st, dec)),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Lowering-path coverage: the sweeps above call kernels directly, so a
# factory regression (wrong wiring, silent decline) would never surface
# there.  These route tiny graphs through lower() and check the kernel
# actually ran — and matched the generic path.
# --------------------------------------------------------------------------


def test_streamfuse_registered_in_lowering(monkeypatch):
    """The motivating chain lowers through the Pallas kernel."""
    import jax

    from repro.core import codo_opt, lower
    from repro.kernels import register_all
    from repro.models.dataflow_models import GB, random_inputs

    register_all()
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny conv: skip cost gate
    b = GB("chain")
    x = b.input("x", (1, 3, 12, 12))
    y = b.conv(x, 4, 3, relu=True)
    b.mark_output(y)
    g = b.g
    c = codo_opt(g)
    low = lower(c, jit=False)
    kernels = {grp.kernel for grp in low.groups}
    assert "pallas:streamfuse.conv" in kernels
    env = random_inputs(g)
    got = low(env)
    want = g.execute(env)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("builder,kname", [
    ("mha_batched", "flashattn.mha"),
    ("rglru_block", "rglru.scan"),
    ("ssd_block", "ssd.scan"),
])
def test_recurrence_factories_exercised_through_lowering(monkeypatch,
                                                         builder, kname):
    """Each recurrence family's factory builds a runnable step through
    lower() whose output matches the generic execution."""
    from repro.core import codo_opt, lower
    from repro.kernels import register_all
    from repro.models import dataflow_models as dm

    register_all()
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    g = getattr(dm, builder)()
    c = codo_opt(g)
    low = lower(c, jit=False)
    assert any(r.kernel == kname for grp in low.groups for r in grp.routes)
    env = dm.random_inputs(g)
    got = low(env)
    want = g.execute(env)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                                   rtol=1e-4, atol=1e-4)


def test_factory_decline_falls_back_to_generic(monkeypatch):
    """A factory returning None (backend decline) must not vanish: the
    chain lands in rejected[] as "declined" and the group still executes
    correctly on the generic path."""
    from dataclasses import replace

    from repro.core import codo_opt, lower
    from repro.core.routing import (register_kernel_pattern,
                                    registered_patterns)
    from repro.kernels import register_all
    from repro.models import dataflow_models as dm

    register_all()
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")
    orig = next(p for p in registered_patterns() if p.name == "rglru.scan")
    register_kernel_pattern(replace(orig, factory=lambda *a, **k: None))
    try:
        g = dm.rglru_block(B=1, S=16, D=8)
        c = codo_opt(g)
        low = lower(c, jit=False)
        assert all(r.kernel != "rglru.scan"
                   for grp in low.groups for r in grp.routes)
        rej = [r for grp in low.groups for r in grp.rejected
               if r.kernel == "rglru.scan"]
        assert rej and all(r.decision == "declined" for r in rej)
        env = dm.random_inputs(g)
        got = low(env)
        want = g.execute(env)
        for k in want:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]),
                                       rtol=1e-5, atol=1e-5)
    finally:
        register_kernel_pattern(orig)
