"""Sharded multi-device execution tests.

Two layers:

* in-process, jax-free unit tests of the pure-data subsystem —
  ``distributed/plan.py`` (MeshSpec/ShardSpec/ShardingPlan), the
  propagation partitioner, the collective-step builder with its
  decomposition thresholds, and the cost-model pricing; plus the v1.5
  artifact plumbing on a single device.
* one subprocess battery under ``XLA_FLAGS=--xla_force_host_platform_
  device_count=8`` that lowers the gpt2_block design through
  ``shard_map`` on a 4x2 mesh and proves every strategy matches the
  single-device numerics (within the documented fp-reassociation band),
  including the forced ring / reduce-scatter+all-gather decompositions
  and the full ``codo.compile(mesh=...) -> export -> codo.load`` round
  trip.
"""

import json
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

from repro.core.costmodel import estimate_sharding
from repro.distributed import collectives as coll
from repro.distributed.partition import PartitionError, partition
from repro.distributed.plan import (COLLECTIVE_KINDS, MeshSpec, ShardSpec,
                                    ShardingPlan)
from repro.models import dataflow_models as dm

SRC = str(Path(__file__).resolve().parents[1] / "src")

MESH42 = MeshSpec((("data", 4), ("model", 2)))


# --------------------------------------------------------------------------
# plan.py (pure data)
# --------------------------------------------------------------------------


def test_mesh_spec_roundtrip_and_validation():
    assert MESH42.size == 8 and MESH42.names == ("data", "model")
    assert MeshSpec.from_dict(MESH42.to_dict()) == MESH42
    with pytest.raises(ValueError):
        MeshSpec((("data", 2), ("data", 4)))       # duplicate axis
    with pytest.raises(ValueError):
        MeshSpec((("data", 0),))                   # non-positive size


def test_shard_spec_local_shape_and_validation():
    s = ShardSpec(("data", None))
    assert s.shard_factor(MESH42) == 4
    assert s.local_shape((8, 6), MESH42) == (2, 6)
    assert ShardSpec((None, None)).is_replicated
    with pytest.raises(ValueError):
        ShardSpec(("data", "data"))                # same axis on two dims


def test_sharding_plan_digest_is_stable_and_tamper_checked():
    plan = partition(dm.gpt2_block(32, 64), MESH42, "dp")
    again = partition(dm.gpt2_block(32, 64), MESH42, "dp")
    assert plan.digest() == again.digest()
    doc = plan.to_dict()
    assert ShardingPlan.from_dict(doc).digest() == plan.digest()
    doc["strategy"] = "tp"                         # tamper
    with pytest.raises(ValueError, match="digest"):
        ShardingPlan.from_dict(doc)


# --------------------------------------------------------------------------
# partition.py + collectives.py (jax-free)
# --------------------------------------------------------------------------


def test_partition_strategies_have_expected_collectives():
    g = dm.gpt2_block(32, 64)
    dp = partition(g, MESH42, "dp")
    tp = partition(g, MESH42, "tp")
    both = partition(g, MESH42, "dp_tp")
    assert {s.kind for s in dp.steps} <= {"all_gather"}
    assert any(s.kind == "psum" for s in tp.steps)
    assert len(both.steps) >= max(len(dp.steps), len(tp.steps))
    for plan in (dp, tp, both):
        assert all(s.kind in COLLECTIVE_KINDS for s in plan.steps)
        assert plan.collective_bytes > 0


def test_partition_auto_picks_cheapest_candidate():
    g = dm.gpt2_block(32, 64)
    auto = partition(g, MESH42, "auto")
    cands = [partition(g, MESH42, s)
             for s in ("replicate", "dp", "tp", "dp_tp")]
    assert auto.estimated_cycles == min(c.estimated_cycles for c in cands)


def test_partition_rejects_bad_inputs():
    g = dm.gpt2_block(32, 64)
    with pytest.raises(PartitionError, match="unknown strategy"):
        partition(g, MESH42, "nope")
    with pytest.raises(PartitionError, match="tensor axis"):
        partition(g, MeshSpec((("data", 8),)), "tp")


def test_estimate_sharding_prices_compute_vs_links():
    g = dm.gpt2_block(32, 64)
    rep = estimate_sharding(g, partition(g, MESH42, "replicate"))
    both = estimate_sharding(g, partition(g, MESH42, "dp_tp"))
    assert rep.collective_cycles == 0
    assert both.collective_cycles > 0
    assert both.compute_cycles < rep.compute_cycles
    assert both.total_cycles < rep.total_cycles


def test_collective_decomposition_thresholds(monkeypatch):
    g = dm.gpt2_block(32, 64)
    direct = partition(g, MESH42, "dp_tp")
    assert {s.via for s in direct.steps} == {"direct"}   # small payloads
    monkeypatch.setenv("CODO_COLLECTIVE_RING_BYTES", "0")
    monkeypatch.setenv("CODO_COLLECTIVE_RSAG_BYTES", "0")
    forced = partition(g, MESH42, "dp_tp")
    vias = {(s.kind, s.via) for s in forced.steps}
    assert ("all_gather", "ring") in vias
    assert ("psum", "rs_ag") in vias
    # decomposition is recorded in the digest: different plan identity
    assert forced.digest() != direct.digest()


def test_collective_steps_carry_fifo_depth_and_bytes():
    g = dm.gpt2_block(32, 64)
    plan = partition(g, MESH42, "dp_tp")
    for s in plan.steps:
        assert s.bytes > 0 and s.depth >= 1
        if s.kind == "psum":
            assert s.chunk_bytes == s.bytes // MESH42.axis_size(s.axis)


# --------------------------------------------------------------------------
# artifact v1.5 plumbing (single device)
# --------------------------------------------------------------------------


def test_artifact_sharding_section_roundtrip(tmp_path):
    from repro import api as codo
    from repro.core.artifact import (diff_artifacts, import_artifact,
                                     validate_artifact)
    prog = codo.compile(dm.gpt2_block(32, 64))
    plan = partition(prog.compiled, MESH42, "dp_tp")
    prog._sharding = plan
    path = tmp_path / "sharded.json"
    prog.export(str(path))
    doc = json.loads(path.read_text())
    assert doc["schema_version"] == "1.5"
    assert doc["sharding"]["strategy"] == "dp_tp"
    assert validate_artifact(doc) == []
    back = import_artifact(str(path))
    assert back.sharding_plan.digest() == plan.digest()

    plain = tmp_path / "plain.json"
    prog._sharding = None
    prog.export(str(plain))
    diffs = diff_artifacts(str(path), str(plain))
    assert any("sharding" in d for d in diffs)


def test_artifact_rejects_corrupt_sharding_section(tmp_path):
    from repro import api as codo
    from repro.core.artifact import ArtifactError, validate_artifact
    prog = codo.compile(dm.gpt2_block(32, 64))
    prog._sharding = partition(prog.compiled, MESH42, "dp")
    path = tmp_path / "a.json"
    prog.export(str(path))
    doc = json.loads(path.read_text())
    doc["sharding"]["specs"]["no_such_buffer"] = {"dims": ["data"]}
    with pytest.raises(ArtifactError, match="no_such_buffer"):
        validate_artifact(doc)


# --------------------------------------------------------------------------
# multi-device battery (subprocess: 8 host devices)
# --------------------------------------------------------------------------

MULTIDEV = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    sys.path.insert(0, {src!r})
    import numpy as np
    import jax

    from repro import api as codo
    from repro.core.lowering import verify_sharding
    from repro.distributed.partition import partition
    from repro.launch.mesh import make_debug_mesh
    from repro.models import dataflow_models as dm

    out = {{}}
    S, D = 64, 128
    graph = dm.gpt2_block(S, D)
    mesh = make_debug_mesh((4, 2), ("data", "model"))
    prog = codo.compile(graph)
    rng = np.random.default_rng(0)
    args = {{n: rng.standard_normal(
        tuple(graph.buffers[n].shape)).astype("float32")
        for n in prog.input_names}}
    env = prog.make_env(**args)

    # 1) every strategy matches single-device numerics
    strat_out = {{}}
    for strat in ("replicate", "dp", "tp", "dp_tp", "auto"):
        plan = partition(prog.compiled, mesh, strat)
        verify_sharding(prog.compiled, plan, dict(env))
        strat_out[strat] = {{
            "resolved": plan.strategy,
            "kinds": sorted(set(s.kind for s in plan.steps)),
            "est": plan.estimated_cycles,
        }}
    out["strategies"] = strat_out

    # 2) forced ring + rs_ag decompositions still verify
    os.environ["CODO_COLLECTIVE_RING_BYTES"] = "0"
    os.environ["CODO_COLLECTIVE_RSAG_BYTES"] = "0"
    forced = partition(prog.compiled, mesh, "dp_tp")
    out["forced_vias"] = sorted(set((s.kind, s.via) for s in forced.steps))
    verify_sharding(prog.compiled, forced, dict(env))
    del os.environ["CODO_COLLECTIVE_RING_BYTES"]
    del os.environ["CODO_COLLECTIVE_RSAG_BYTES"]

    # 3) full api path: compile(mesh=...) -> verify -> export -> load
    sh = codo.compile(graph, mesh=mesh)
    out["api_strategy"] = sh.sharding.strategy
    sh.verify(**args)
    low_sh = sh.lower(jit=True)
    out["lower_memoized"] = low_sh is sh.lower(jit=True)
    y_sh = low_sh(sh.make_env(**args))
    y_1 = prog.lower(jit=True)(prog.make_env(**args))
    errs = [float(np.abs(np.asarray(y_sh[k]) - np.asarray(y_1[k])).max())
            for k in y_1]
    out["jit_max_abs_err"] = max(errs)

    path = "sharded_artifact.json"
    sh.export(path, weights={{n: env[n] for n in env
                             if graph.buffers[n].kind == "weight"}})
    back = codo.load(path)
    out["loaded_digest_match"] = (back.sharding.digest()
                                  == sh.sharding.digest())
    out["schema"] = json.load(open(path))["schema_version"]

    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def sharded_results(tmp_path_factory):
    script = MULTIDEV.format(src=SRC)
    proc = subprocess.run([sys.executable, "-c", script],
                          capture_output=True, text=True, timeout=600,
                          cwd=tmp_path_factory.mktemp("sharding"))
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT")][-1]
    return json.loads(line[len("RESULT"):])


def test_all_strategies_match_single_device(sharded_results):
    st = sharded_results["strategies"]
    assert set(st) == {"replicate", "dp", "tp", "dp_tp", "auto"}
    assert st["dp"]["kinds"] in ([], ["all_gather"])
    assert "psum" in st["tp"]["kinds"]
    # auto resolved to a named candidate with the lowest estimate
    named = {k: v["est"] for k, v in st.items() if k != "auto"}
    assert st["auto"]["resolved"] in named
    assert st["auto"]["est"] == min(named.values())


def test_forced_decompositions_verify(sharded_results):
    vias = [tuple(v) for v in sharded_results["forced_vias"]]
    assert ("all_gather", "ring") in vias
    assert ("psum", "rs_ag") in vias


def test_api_sharded_jit_matches_single_device(sharded_results):
    assert sharded_results["jit_max_abs_err"] < 5e-4
    assert sharded_results["lower_memoized"]


def test_sharding_plan_survives_export_load(sharded_results):
    assert sharded_results["schema"] == "1.5"
    assert sharded_results["loaded_digest_match"]
    assert sharded_results["api_strategy"] in ("replicate", "dp", "tp",
                                               "dp_tp")
