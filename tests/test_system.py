"""End-to-end system tests: the full CODO pipeline on the paper's
workloads — violation elimination → buffers → scheduling → lowering —
checked for correctness, ablation ordering (Table VII), and compile time."""

import numpy as np
import pytest

from repro.core import (CodoOptions, codo_opt, lower, verify_lowering,
                        verify_violation_free)
from repro.models import dataflow_models as dm

SMALL = {
    "atax": lambda: dm.atax(48, 48),
    "gesummv": lambda: dm.gesummv(48),
    "gemm": lambda: dm.gemm(32, 32, 32),
    "mvt": lambda: dm.mvt(48),
    "3mm": lambda: dm.three_mm(32),
    "residual_mlp": lambda: dm.residual_mlp(8, 32),
    "autoencoder": lambda: dm.autoencoder(8, 64),
    "residual_block": lambda: dm.residual_block(1, 8, 12),
    "dws_conv_block": lambda: dm.dws_conv_block(1, 8, 12),
    "conv3_block": lambda: dm.conv3_block(1, 3, 14),
    "feed_forward": lambda: dm.feed_forward(16, 32),
    "multi_head_attention": lambda: dm.multi_head_attention(24, 32),
    "gpt2_block": lambda: dm.gpt2_block(32, 64),
    "resnet18": lambda: dm.resnet18(32),
}


@pytest.mark.parametrize("name", sorted(SMALL))
def test_workload_compiles_violation_free(name):
    g = SMALL[name]()
    g.validate()
    c = codo_opt(g)
    assert not verify_violation_free(c)
    assert c.speedup >= 1.0
    assert 0.0 < c.fifo_fraction <= 1.0


@pytest.mark.parametrize("name", sorted(SMALL))
def test_workload_lowering_matches_oracle(name):
    g = SMALL[name]()
    c = codo_opt(g)
    env = dm.random_inputs(g)
    verify_lowering(g, c, env, rtol=3e-4, atol=3e-4)


def test_ablation_ordering_fig10():
    """Opt1 (no coarse) ~ sequential; Opt5 strictly best (Fig. 10)."""
    g = dm.resnet18(32)
    speed = {}
    for name, opt in [("opt1", CodoOptions.opt1()), ("opt2", CodoOptions.opt2()),
                      ("opt3", CodoOptions.opt3()), ("opt4", CodoOptions.opt4()),
                      ("opt5", CodoOptions.opt5())]:
        speed[name] = codo_opt(g, opt).speedup
    assert speed["opt1"] < 1.5            # unresolved coarse -> ~sequential
    assert speed["opt5"] > speed["opt4"]  # scheduling dominates
    assert speed["opt5"] > 50             # large-model speedups (Table III scale)
    assert speed["opt4"] >= speed["opt2"] * 0.9


def test_fifo_percentage_table8():
    """Table VIII: high FIFO share on the quoted workloads."""
    expect_min = {
        "gesummv": 1.0, "residual_block": 0.7, "multi_head_attention": 0.8,
        "resnet18": 0.7,
    }
    for name, lo in expect_min.items():
        c = codo_opt(SMALL[name]())
        assert c.fifo_fraction >= lo, (name, c.fifo_fraction)


def test_compile_time_seconds_not_minutes():
    """Paper: CODO DSE takes ~seconds (Table II/III) where MINLP takes
    minutes-hours; our full pipeline on ResNet-18 must stay < 10 s."""
    c = codo_opt(dm.resnet18(32))
    assert c.compile_seconds < 10.0


def test_dnn_speedups_scale_with_models():
    """Larger CNNs expose more dataflow overlap (Tables III vs IV trend)."""
    small = codo_opt(dm.vgg16(32)).speedup
    assert small > 10


def test_scheduler_balances_bottleneck():
    from repro.core.costmodel import task_cost

    g = dm.conv3_block(1, 3, 18)
    c = codo_opt(g)
    # bottleneck got parallelized
    hot = max(c.graph.tasks, key=lambda t: t.flops)
    assert any(l.parallel > 1 for l in hot.loops)
