"""Docs stay executable: run every ```python block in the README and the
architecture walkthrough (the same check the CI docs job performs via
tools/check_docs.py)."""

import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "tools"))

from check_docs import check_file, extract_blocks  # noqa: E402

DOCS = [ROOT / "README.md", ROOT / "docs" / "architecture.md",
        ROOT / "docs" / "artifact_format.md", ROOT / "docs" / "autodiff.md",
        ROOT / "docs" / "frontend.md", ROOT / "docs" / "serving.md",
        ROOT / "docs" / "sharding.md"]


def test_docs_exist_and_have_python_blocks():
    for doc in DOCS:
        assert doc.exists(), doc
        assert extract_blocks(doc.read_text()), f"{doc} has no python blocks"


@pytest.mark.parametrize("doc", DOCS, ids=lambda p: p.name)
def test_doc_code_blocks_execute(doc):
    assert check_file(doc) == 0
