"""Training loop, optimizer, checkpoint/restart, data pipeline,
straggler/heartbeat, gradient compression."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM
from repro.distributed import compression
from repro.models import transformer as tf
from repro.training.optimizer import (OptConfig, adamw_init, adamw_update,
                                      global_norm, lr_at)
from repro.training.train_loop import (Heartbeat, SimulatedFailure,
                                       StepTimeMonitor, resume, train)

CFG = get_config("gpt2-medium").smoke()


def _batch_fn(step, batch=2, seq=64):
    src = SyntheticLM(CFG, DataConfig(seq_len=seq, global_batch=batch, seed=1))
    b = src.batch(step)
    return {k: jnp.asarray(v) for k, v in b.items()}


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------


def test_adamw_decreases_loss():
    # overfit one fixed batch: memorization must drive the loss down
    fixed = _batch_fn(0, batch=4, seq=64)
    params, opt, rep = train(CFG, steps=25, batch_fn=lambda s: fixed,
                             oc=OptConfig(lr=1e-2, warmup_steps=2,
                                          total_steps=25, weight_decay=0.0),
                             remat=False)
    assert rep.losses[-1] < rep.losses[0] - 0.2


def test_lr_schedule_shape():
    oc = OptConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_frac=0.1)
    lrs = [float(lr_at(jnp.asarray(s), oc)) for s in range(100)]
    assert lrs[0] < lrs[9]                        # warmup ramps
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < lrs[15]                      # cosine decays
    assert lrs[-1] >= 0.1 * 0.99                  # floor


def test_grad_clip():
    tree = {"a": jnp.full((4,), 100.0)}
    from repro.training.optimizer import clip_by_global_norm
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) <= 1.0 + 1e-5
    assert float(norm) > 1.0


# --------------------------------------------------------------------------
# checkpoint / restart / elasticity
# --------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    params = tf.init_params(CFG, jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    ck.save(3, state, blocking=True)
    step, restored = ck.restore_latest(state)
    assert step == 3
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_corruption(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    state = {"w": jnp.arange(8.0)}
    for s in (1, 2, 3):
        ck.save(s, state, blocking=True)
    assert ck.steps() == [2, 3]                  # keep=2 collected step 1
    # corrupt newest -> restore falls back to step 2
    victim = next((tmp_path / "step_3").glob("*.npy"))
    victim.write_bytes(b"garbage" * 10)
    step, _ = ck.restore_latest({"w": jnp.zeros(8)})
    assert step == 2


def test_failure_injection_and_resume(tmp_path):
    ck = Checkpointer(tmp_path)
    with pytest.raises(SimulatedFailure):
        train(CFG, steps=10, batch_fn=_batch_fn, checkpointer=ck,
              checkpoint_every=2, fail_at=5, remat=False)
    ck.wait()
    assert ck.steps()                            # progress survived
    params, opt, rep = resume(CFG, ck, steps=8, batch_fn=_batch_fn,
                              checkpoint_every=100, remat=False)
    assert rep.steps_done == 8
    assert rep.losses                            # continued past the failure


def test_resume_bitwise_equivalent(tmp_path):
    """restart from step 4 reproduces the uninterrupted run exactly
    (deterministic data + state restore)."""
    oc = OptConfig(lr=1e-3, warmup_steps=1, total_steps=8)
    p_full, _, _ = train(CFG, steps=8, batch_fn=_batch_fn, oc=oc, remat=False)
    ck = Checkpointer(tmp_path)
    train(CFG, steps=4, batch_fn=_batch_fn, checkpointer=ck,
          checkpoint_every=4, oc=oc, remat=False)
    ck.wait()
    p_res, _, _ = resume(CFG, ck, steps=8, batch_fn=_batch_fn, oc=oc,
                         checkpoint_every=100, remat=False)
    for a, b in zip(jax.tree.leaves(p_full), jax.tree.leaves(p_res)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# monitors
# --------------------------------------------------------------------------


def test_straggler_monitor():
    m = StepTimeMonitor(k=3.0)
    assert not m.observe(1.0)
    assert not m.observe(1.1)
    assert m.observe(10.0)                       # 10x the mean -> flagged
    assert m.flags == 1


def test_heartbeat():
    hb = Heartbeat(timeout_s=0.05)
    hb.beat()
    assert not hb.expired()
    time.sleep(0.08)
    assert hb.expired()


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------


def test_data_determinism_and_host_sharding():
    dc = DataConfig(seq_len=32, global_batch=8, seed=5)
    a = SyntheticLM(CFG, dc).batch(7)
    b = SyntheticLM(CFG, dc).batch(7)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # two hosts partition the same global batch
    h0 = SyntheticLM(CFG, DataConfig(32, 8, 5, num_hosts=2, host_index=0)).batch(7)
    h1 = SyntheticLM(CFG, DataConfig(32, 8, 5, num_hosts=2, host_index=1)).batch(7)
    glob = np.concatenate([h0["tokens"], h1["tokens"]])
    np.testing.assert_array_equal(glob, a["tokens"])


def test_prefetcher_streams_in_order():
    src = SyntheticLM(CFG, DataConfig(seq_len=16, global_batch=2, seed=0))
    pf = Prefetcher(src, start_step=0, depth=2)
    steps = [pf.next()[0] for _ in range(4)]
    pf.close()
    assert steps == [0, 1, 2, 3]


def test_label_masking():
    dc = DataConfig(seq_len=256, global_batch=2, seed=3, mean_doc_len=32)
    b = SyntheticLM(CFG, dc).batch(0)
    assert (b["labels"] == -100).any()           # packed boundaries masked


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------


def test_quantize_roundtrip_error_bound():
    g = jnp.asarray(np.random.default_rng(0).standard_normal(512), jnp.float32)
    q, s = compression.quantize(g)
    err = np.abs(np.asarray(compression.dequantize(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-7    # half-step rounding bound


def test_error_feedback_reduces_bias():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(256) * 1e-3, jnp.float32)
    ef = None
    acc_plain = np.zeros(256)
    acc_ef = np.zeros(256)
    ef_state = jax.tree.map(lambda x: jnp.zeros_like(x), {"g": g})
    carried = {"g": jnp.zeros_like(g)}
    for _ in range(20):
        q, s, _ = compression.compress_tree({"g": g})
        acc_plain += np.asarray(compression.dequantize(q["g"], s["g"]))
        q2, s2, carried = compression.compress_tree({"g": g}, carried)
        acc_ef += np.asarray(compression.dequantize(q2["g"], s2["g"]))
    want = np.asarray(g) * 20
    assert np.abs(acc_ef - want).mean() <= np.abs(acc_plain - want).mean() + 1e-9


def test_wire_bytes_shrink():
    tree = {"w": jnp.zeros((1024,), jnp.float32)}
    assert compression.wire_bytes(tree, True) < compression.wire_bytes(tree, False) / 3
