"""Cold-restart round-trip: compiled designs are portable artifacts.

Compiles in this process with a disk cache, then starts a *fresh
interpreter* (subprocess) that reloads the entry from disk, lowers it,
executes it, and checks the outputs against the un-optimized oracle —
the end-to-end property the declarative op registry exists to provide.
"""

import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import CompileCache, CodoOptions, codo_opt
from repro.models import dataflow_models as dm

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _fresh_interpreter(code: str) -> subprocess.CompletedProcess:
    env = dict(os.environ, JAX_PLATFORMS="cpu",
               PYTHONPATH=_SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    return subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, timeout=600, env=env)


def test_cold_restart_disk_hit_lowers_executes_and_verifies(tmp_path):
    cache_dir = tmp_path / "cc"
    opts = CodoOptions(budget_units=64)
    c = codo_opt(dm.residual_block(1, 8, 12),
                 opts, cache=CompileCache(disk_dir=cache_dir))
    assert not c.cache_hit and list(cache_dir.glob("*.pkl"))

    proc = _fresh_interpreter(f"""
        from repro.core import (CompileCache, CodoOptions, codo_opt, lower,
                                verify_lowering)
        from repro.core.passes import PASS_RUN_COUNTS
        from repro.models import dataflow_models as dm

        src = dm.residual_block(1, 8, 12)
        cache = CompileCache(disk_dir={str(cache_dir)!r})
        c = codo_opt(src, CodoOptions(budget_units=64), cache=cache)
        assert c.cache_hit, "fresh interpreter must hit the disk tier"
        assert cache.stats.disk_hits == 1
        assert not PASS_RUN_COUNTS, "disk hit must not run any pass"
        assert all(t.fn is not None for t in c.graph.tasks), "stripped fns"
        assert all(not t.fn_is_closure for t in c.graph.tasks)

        # the reloaded design lowers, executes, and matches the oracle
        env = dm.random_inputs(src)
        low = lower(c, jit=False)
        out = low(env)
        assert set(out) == {{b.name for b in c.graph.outputs()}}
        verify_lowering(src, c, env, rtol=3e-4, atol=3e-4)
        print("COLD_RESTART_OK", c.speedup)
    """)
    assert proc.returncode == 0, proc.stderr
    assert "COLD_RESTART_OK" in proc.stdout
    # same design, same estimate across interpreters
    reported = float(proc.stdout.split("COLD_RESTART_OK")[1].split()[0])
    np.testing.assert_allclose(reported, c.speedup, rtol=1e-9)


def test_cold_restart_batch_grid_round_trips(tmp_path):
    """The batch CLI analogue: a warm second interpreter serves the whole
    (config × preset) sub-grid from disk and the entries stay executable."""
    from repro.core.compiler import ablation_jobs, batch_workloads, codo_opt_batch

    cache_dir = tmp_path / "cc"
    wl = batch_workloads(seq=8)
    sub = {k: wl[k] for k in ("gpt2-medium",)}
    jobs = ablation_jobs(sub, presets=["opt2", "opt5"], budget_units=64)
    res = codo_opt_batch(jobs, cache=CompileCache(disk_dir=cache_dir),
                         max_workers=1)
    assert all(r.ok and not r.cache_hit for r in res)

    proc = _fresh_interpreter(f"""
        from repro.core import CompileCache
        from repro.core.compiler import (ablation_jobs, batch_workloads,
                                         codo_opt_batch)
        wl = batch_workloads(seq=8)
        jobs = ablation_jobs({{"gpt2-medium": wl["gpt2-medium"]}},
                             presets=["opt2", "opt5"], budget_units=64)
        res = codo_opt_batch(jobs, cache=CompileCache(disk_dir={str(cache_dir)!r}),
                             max_workers=1)
        assert all(r.ok and r.cache_hit for r in res), [r.error for r in res]
        assert all(t.fn is not None
                   for r in res for t in r.compiled.graph.tasks)
        print("BATCH_RELOAD_OK")
    """)
    assert proc.returncode == 0, proc.stderr
    assert "BATCH_RELOAD_OK" in proc.stdout
