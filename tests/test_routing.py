"""Pattern-routed Pallas lowering (repro.core.routing + kernel patterns).

Covers the ISSUE-5 acceptance criteria: the subsequence matcher over
fusion-group OpSpec chains, feasibility guards, routed-vs-generic
numerics on ``gpt2_block``/``resnet18`` (both the fused-reference backend
and the true Pallas interpret path), the ``CODO_DISABLE_PALLAS`` escape
hatch and its lowering-memo-key coverage, routing decisions riding on
diagnostics and artifacts, and the CLI ``--profile`` routing table.

Since ISSUE 6 routing is cost-gated: tiny unit-test shapes fall below
the predictor's win threshold, so shape-dependent routing tests pin
``CODO_FORCE_PALLAS=1`` to exercise the kernel path deterministically.
The gate itself is covered in ``tests/test_costmodel_routing.py``.
"""

import numpy as np
import pytest

from repro.core import CodoOptions, codo_opt
from repro.core.compiler import main as compiler_main
from repro.core.lowering import (LOWER_CACHE_STATS, clear_lower_cache,
                                 fusion_groups, lower, verify_routing)
from repro.core.routing import (XLA_FUSED, KernelPattern, match_group,
                                pallas_disabled, registered_patterns,
                                route_plan)
from repro.kernels import register_all
from repro.kernels.streamfuse import (fused_matmul_chain,
                                      fused_softmax_matmul,
                                      matmul_chain_ref, softmax_matmul_ref)
from repro.models import dataflow_models as dm

register_all()

RNG = np.random.default_rng(7)


def _compile(graph, budget=64):
    return codo_opt(graph, CodoOptions.preset("opt5", budget_units=budget),
                    cache=None)


def _gpt2():
    return _compile(dm.gpt2_block(S=16, D=64))


def _resnet():
    return _compile(dm.resnet18(16))


# --------------------------------------------------------------------------
# The matcher
# --------------------------------------------------------------------------


def _groups_and_impl(compiled):
    impl = compiled.buffer_plan.impl if compiled.buffer_plan else {}
    return fusion_groups(compiled.graph, impl), impl


def test_exact_and_wildcard_matching():
    c = _compile(dm.feed_forward(8, 16))        # matmul -> gelu -> matmul
    groups, impl = _groups_and_impl(c)
    g = max(groups, key=lambda g: len(g.tasks))
    pat = KernelPattern("t", ("matmul", "*ewise", "matmul"),
                        factory=lambda *a: None)
    matches = match_group(c.graph, g.tasks, impl, patterns=[pat])
    assert len(matches) == 1
    ops = [t.op for t in matches[0][1]]
    assert ops == ["matmul", "ewise", "matmul"]   # wildcard took the gelu

    # zero-width wildcard: a bare matmul->matmul chain also matches
    c2 = _compile(dm.three_mm(8))
    groups2, impl2 = _groups_and_impl(c2)
    g2 = max(groups2, key=lambda g: len(g.tasks))
    m2 = match_group(c2.graph, g2.tasks, impl2, patterns=[pat])
    assert m2 and all(len(ts) >= 2 for _p, ts in m2)


def test_matches_never_overlap_and_skip_single_tasks():
    c = _gpt2()
    groups, impl = _groups_and_impl(c)
    g = groups[0]
    claimed = []
    for _p, tasks in match_group(c.graph, g.tasks, impl):
        assert len(tasks) >= 2
        for t in tasks:
            assert t.name not in claimed, "overlapping claims"
            claimed.append(t.name)


def test_feasibility_guards_reject_mv_chains_and_strided_convs():
    # atax is mv->mv: op pattern matches but the spec kinds are not 2-D
    # matmuls, so the mmchain guard declines.
    c = _compile(dm.atax(24, 24))
    groups, impl = _groups_and_impl(c)
    for g in groups:
        for pat, _tasks in match_group(c.graph, g.tasks, impl):
            assert pat.name != "streamfuse.mmchain"
    # stride-2 convs in resnet never route to streamfuse.conv
    c2 = _resnet()
    low = lower(c2, jit=False)
    for g in low.groups:
        for r in g.routes:
            conv = next(c2.graph.task(n) for n in r.tasks
                        if c2.graph.task(n).op == "conv")
            assert int(conv.spec.attrs.get("stride", 1)) == 1


def test_chain_operand_reuse_does_not_route():
    """A task consuming the chain value through a *second* operand slot
    (p @ p) cannot be folded into a kernel that never emits the interior
    — such graphs must stay on the generic path and still execute."""
    from repro.core import frontend as F

    def pp(s):
        p = F.softmax(s)
        return F.matmul(p, p)                # softmax -> matmul, but v is p

    c = _compile(F.trace(pp, (8, 8), name="pp"))
    low = lower(c, jit=False)
    assert all("softmaxmm" not in r.kernel
               for g in low.groups for r in g.routes)
    env = dm.random_inputs(c.graph)
    low(env)                                 # executes — no KeyError
    verify_routing(c, env)

    def hh(a, w):
        h = F.matmul(a, w)
        return F.matmul(h, h)                # (a@w) @ (a@w)

    c2 = _compile(F.trace(hh, (8, 8), (8, 8), name="hh"))
    low2 = lower(c2, jit=False)
    assert all("mmchain" not in r.kernel
               for g in low2.groups for r in g.routes)
    verify_routing(c2, dm.random_inputs(c2.graph))


def test_wildcard_cannot_anchor_pattern():
    with pytest.raises(ValueError, match="wildcard"):
        KernelPattern("bad", ("*ewise", "matmul"), factory=lambda *a: None)


def test_legacy_register_group_kernel_shim():
    from repro.core.lowering import register_group_kernel
    register_group_kernel(("pool", "pool", "pool"), lambda graph, group: None)
    names = {p.name: p for p in registered_patterns()}
    assert names["pool+pool+pool"].pattern == ("pool", "pool", "pool")


# --------------------------------------------------------------------------
# Acceptance: gpt2_block and resnet18 route and verify
# --------------------------------------------------------------------------


def test_gpt2_block_routes_and_verifies(monkeypatch):
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    c = _gpt2()
    low = lower(c, jit=False)
    routed = [g for g in low.groups if g.routes]
    assert routed, "gpt2_block must route at least one fusion group"
    kernels = {r.kernel for g in routed for r in g.routes}
    assert "streamfuse.mmchain" in kernels           # the FFN chain
    # The full attention chain goes to flashattn — which supersedes the
    # softmaxmm tail (matmul -> scale -> softmax -> matmul claimed whole).
    assert "flashattn.mha" in kernels
    assert "streamfuse.softmaxmm" not in kernels
    env = dm.random_inputs(c.graph)
    verify_routing(c, env, rtol=3e-4, atol=3e-4)
    # the decision rides on the diagnostics, with the gate's estimates
    entries = c.diagnostics.group_kernels.values()
    assert any(e["kernel"] != XLA_FUSED for e in entries)
    assert all(e["decision"] and "predicted_routed_cycles" in e
               for e in entries)
    assert "pallas-routed" in c.diagnostics.summary()


def test_resnet18_routes_and_verifies():
    c = _resnet()
    low = lower(c, jit=False)
    conv_routed = [g for g in low.groups
                   if any(r.kernel == "streamfuse.conv" for r in g.routes)]
    assert conv_routed, "resnet18 must route conv chains"
    env = dm.random_inputs(c.graph)
    verify_routing(c, env, rtol=3e-4, atol=3e-4)


def test_routed_interior_buffers_never_materialize():
    c = _gpt2()
    low = lower(c, jit=False)
    interior = {c.graph.task(n).writes[0].buffer
                for g in low.groups for r in g.routes for n in r.tasks[:-1]}
    assert interior.isdisjoint(low.materialized)
    out = low(dm.random_inputs(c.graph))
    assert set(out) == {b.name for b in c.graph.outputs()}


def test_true_pallas_interpret_path(monkeypatch):
    """CODO_PALLAS_INTERPRET=1 runs the real Pallas kernel bodies (in
    interpret mode on CPU) through the routed lowering — the mmchain and
    flashattn kernels via gpt2, the conv kernel via the Fig. 2 chain."""
    monkeypatch.setenv("CODO_PALLAS_INTERPRET", "1")
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    c = _gpt2()
    env = dm.random_inputs(c.graph)
    routed = verify_routing(c, env, rtol=3e-4, atol=3e-4)
    assert any(g.routes for g in routed.groups)

    c2 = _compile(dm.conv3_block(1, 3, 10))
    routed2 = verify_routing(c2, dm.random_inputs(c2.graph),
                             rtol=3e-4, atol=3e-4)
    assert any(r.kernel == "streamfuse.conv"
               for g in routed2.groups for r in g.routes)


# --------------------------------------------------------------------------
# The kernels themselves, against their refs (interpret mode)
# --------------------------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 16, 24, 12), (16, 32, 8, 16)])
def test_fused_matmul_chain_matches_ref(shape):
    import jax.nn
    M, K, N1, N2 = shape
    a = RNG.standard_normal((M, K)).astype(np.float32)
    w1 = RNG.standard_normal((K, N1)).astype(np.float32)
    w2 = RNG.standard_normal((N1, N2)).astype(np.float32)
    for ew in ((), jax.nn.gelu):
        got = fused_matmul_chain(a, w1, w2, ew=ew, interpret=True)
        want = matmul_chain_ref(a, w1, w2, ew)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", [(8, 24, 12), (16, 32, 8)])
def test_fused_softmax_matmul_matches_ref(shape):
    M, K, N = shape
    s = (RNG.standard_normal((M, K)) * 3).astype(np.float32)
    v = RNG.standard_normal((K, N)).astype(np.float32)
    got = fused_softmax_matmul(s, v, block_k=8, interpret=True)
    want = softmax_matmul_ref(s, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


# --------------------------------------------------------------------------
# Escape hatch + memo key (satellite: stale-program audit)
# --------------------------------------------------------------------------


def test_disable_pallas_routes_everything_to_xla(monkeypatch):
    monkeypatch.setenv("CODO_DISABLE_PALLAS", "1")
    assert pallas_disabled()
    c = _gpt2()
    low = lower(c, jit=False)
    assert all(g.kernel == XLA_FUSED and not g.routes for g in low.groups)
    verify_routing(c, dm.random_inputs(c.graph))   # trivially equal
    assert all(e["kernel"] == XLA_FUSED
               for e in c.diagnostics.group_kernels.values())


def test_flipping_disable_flag_relowers(monkeypatch):
    """Toggling CODO_DISABLE_PALLAS must never serve a memoized program
    built under the other setting — the flag is part of the memo key."""
    monkeypatch.delenv("CODO_DISABLE_PALLAS", raising=False)
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    c = _gpt2()
    lower(c, jit=False)          # assigns fused_group ids (hash settles)
    clear_lower_cache()
    low_on = lower(c, jit=False)
    assert any(g.routes for g in low_on.groups)
    assert LOWER_CACHE_STATS["misses"] == 1
    lower(c, jit=False)                      # same key: a hit
    assert LOWER_CACHE_STATS["hits"] == 1

    monkeypatch.setenv("CODO_DISABLE_PALLAS", "1")
    low_off = lower(c, jit=False)            # flipped: must re-lower
    assert LOWER_CACHE_STATS["misses"] == 2
    assert all(not g.routes for g in low_off.groups)

    monkeypatch.delenv("CODO_DISABLE_PALLAS")
    low_back = lower(c, jit=False)           # back: the routed entry again
    assert LOWER_CACHE_STATS["hits"] == 2
    assert any(g.routes for g in low_back.groups)


def test_interpret_flag_is_in_memo_key(monkeypatch):
    monkeypatch.delenv("CODO_PALLAS_INTERPRET", raising=False)
    c = _gpt2()
    lower(c, jit=False)          # settle fused_group ids
    clear_lower_cache()
    lower(c, jit=False)
    monkeypatch.setenv("CODO_PALLAS_INTERPRET", "1")
    lower(c, jit=False)
    assert LOWER_CACHE_STATS["misses"] == 2


# --------------------------------------------------------------------------
# Routing rides on artifacts (v1.2) and the CLI --profile table
# --------------------------------------------------------------------------


def test_artifact_records_group_kernels(monkeypatch):
    from repro.core import export_artifact, import_artifact
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    c = _gpt2()
    lower(c, jit=False)
    doc = export_artifact(c)
    assert doc["schema_version"] == "1.5"
    kernels = doc["fusion"]["kernels"]
    assert len(kernels) == len(doc["fusion"]["groups"])
    assert any(k.startswith("pallas:") for k in kernels)
    restored = import_artifact(doc)          # same registry: no drift warn
    assert restored.diagnostics.group_kernels == c.diagnostics.group_kernels


def test_route_plan_is_jax_free_view(monkeypatch):
    monkeypatch.setenv("CODO_FORCE_PALLAS", "1")   # tiny shapes: skip gate
    c = _gpt2()
    impl = c.buffer_plan.impl if c.buffer_plan else {}
    plan = route_plan(c.graph, impl)
    assert any(p["kernel"].startswith("pallas:") for p in plan)
    assert all(set(p) == {"gid", "tasks", "kernel", "routes", "rejected"}
               for p in plan)


def test_cli_profile_prints_routing_table(tmp_path, capsys):
    rc = compiler_main(["--configs", "gpt2_block", "--opts", "opt5",
                        "--executor", "thread", "--jobs", "1", "--no-cache",
                        "--seq", "16", "--profile"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "kernel routing" in out
    assert "gpt2_block/opt5: 1/1 groups pallas-routed" in out
    assert "streamfuse.mmchain" in out
