#!/usr/bin/env python
"""Execute every fenced ```python code block in the given markdown files.

    PYTHONPATH=src python tools/check_docs.py README.md docs/architecture.md

Blocks in one file run top to bottom in a single shared namespace, so a
document can build up an example across sections (exactly how a reader
would follow it).  A block whose first line is ``# doc: skip`` is parsed
(compiled) but not executed — for snippets that need unavailable hardware
or external state.  Any exception fails the check with the offending file
and block number, which makes this the CI gate that keeps the docs from
drifting away from the API.
"""

from __future__ import annotations

import pathlib
import sys
import traceback

# Self-sufficient: doc blocks import repro.* regardless of PYTHONPATH.
_SRC = str(pathlib.Path(__file__).resolve().parents[1] / "src")
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)


def extract_blocks(text: str) -> list[tuple[int, str]]:
    """(starting line number, source) for every ```python fenced block.
    Fences indented inside lists are supported: the fence's indentation is
    stripped from every block line."""
    blocks = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        if lines[i].strip() == "```python":
            indent = len(lines[i]) - len(lines[i].lstrip())
            start = i + 1
            j = start
            while j < len(lines) and lines[j].strip() != "```":
                j += 1
            body = [ln[indent:] if ln[:indent].isspace() or not ln[:indent]
                    else ln for ln in lines[start:j]]
            blocks.append((start + 1, "\n".join(body)))
            i = j + 1
        else:
            i += 1
    return blocks


def check_file(path: pathlib.Path) -> int:
    text = path.read_text()
    blocks = extract_blocks(text)
    namespace: dict = {"__name__": f"doccheck_{path.stem}"}
    failures = 0
    for k, (lineno, src) in enumerate(blocks, 1):
        skip = src.lstrip().startswith("# doc: skip")
        try:
            code = compile(src, f"{path}:block{k}(line {lineno})", "exec")
            if not skip:
                exec(code, namespace)
        except Exception:
            failures += 1
            print(f"FAIL {path} block {k} (line {lineno}):", file=sys.stderr)
            traceback.print_exc()
        else:
            print(f"ok   {path} block {k} (line {lineno})"
                  + (" [compile-only]" if skip else ""))
    print(f"{path}: {len(blocks)} python blocks, {failures} failures")
    return failures


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: check_docs.py FILE.md [FILE.md ...]", file=sys.stderr)
        return 2
    total = 0
    for name in argv:
        total += check_file(pathlib.Path(name))
    return 1 if total else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
