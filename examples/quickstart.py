"""Quickstart: compile the paper's motivating example (Fig. 2) with CODO.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --cache-dir /tmp/codo_cache

The workload is a *plain Python function* — ``codo.compile`` traces it
over symbolic shapes into the pad→conv→relu task graph (declarative op
semantics throughout), shows the detected dataflow violations, runs the
full codo_opt pipeline (coarse + fine elimination, reuse buffers, buffer
determination, auto-scheduling), executes the lowered design, and checks
it against both the eager function and the unoptimized oracle.

With ``--cache-dir`` it also demonstrates the cold-restart property: the
compile is written to an on-disk cache, reloaded through a *fresh* cache
instance (the in-process analogue of a new interpreter — run the script
twice to see a true cold restart), and the reloaded design still lowers
and executes without recompiling.

With ``--artifact PATH`` it exports the compiled design as a versioned
JSON artifact (docs/artifact_format.md), re-imports it with ``codo.load``,
and runs the imported design — the same flow as the compiler CLI's
``--export`` / ``--import-artifact`` verbs and ``repro.launch.serve
--artifact``.

The task-by-task ``GB`` builder + ``codo_opt`` road this example used to
take still works (see "The low-level escape hatch" in the README); the
traced function compiles to the *identical* graph — same structural hash,
same compile-cache entry.
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import numpy as np  # noqa: E402

import codo  # noqa: E402
from repro.core import CompileCache, violation_report  # noqa: E402
from repro.kernels import register_all  # noqa: E402


def motivating(x):
    """Fig. 2: one padded conv + relu — traced into pad -> conv -> relu
    tasks with an order-mismatch violation on the pad->conv edge."""
    return codo.F.conv(x, 8, 3, relu=True)


SHAPE = (1, 3, 32, 32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", default="",
                    help="disk compile-cache dir: demonstrates that a "
                         "reloaded (cold-restart) compile still executes")
    ap.add_argument("--artifact", default="",
                    help="also export/import the design as a versioned "
                         "JSON artifact at this path")
    args = ap.parse_args()

    register_all()                     # route fusion groups to Pallas kernels

    program = codo.compile(motivating, SHAPE, name="motivating")
    g = program.source

    print("== traced dataflow graph ==")
    print(g.summary())
    print("   task specs:", {t.name: t.spec.kind for t in g.tasks})
    print("\n== violations before compilation ==")
    print(violation_report(g))

    print("\n== codo.compile ==")
    print(program.report())

    low = program.lower(jit=False)
    print("\n== lowering ==")
    print(low.summary())
    for grp in low.groups:
        print(f"  group {grp.gid}: {grp.tasks} -> {grp.kernel}")

    x = np.random.default_rng(0).standard_normal(SHAPE).astype(np.float32)
    y = program(x)
    y_eager = motivating(x)            # the same function, run eagerly
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_eager),
                               rtol=1e-5, atol=1e-5)
    program.verify(x)
    print(f"\ncompiled(x) == motivating(x) == oracle ✓  (output {y.shape})")

    if args.artifact:
        print(f"\n== portable artifact (JSON at {args.artifact}) ==")
        program.export(args.artifact)
        imported = codo.load(args.artifact)
        assert (imported.graph.structural_hash()
                == program.graph.structural_hash())
        np.testing.assert_allclose(np.asarray(imported(x)), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)
        print("  imported design lowered and executed ✓")
        print("  CLI equivalents:")
        print("    python -m repro.core.compiler --import-artifact "
              f"{args.artifact}")
        print(f"    python -m repro.launch.serve --artifact {args.artifact}")

    if args.cache_dir:
        print(f"\n== cold-restart demo (disk cache at {args.cache_dir}) ==")
        codo.compile(motivating, SHAPE, name="motivating",
                     cache=CompileCache(disk_dir=args.cache_dir))
        fresh = CompileCache(disk_dir=args.cache_dir)  # knows nothing in memory
        reloaded = codo.compile(motivating, SHAPE, name="motivating",
                                cache=fresh)
        print(f"  reload: cache_hit={reloaded.cache_hit} "
              f"(disk hits: {fresh.stats.disk_hits})")
        assert all(t.fn is not None for t in reloaded.graph.tasks), \
            "disk entry came back stripped"
        np.testing.assert_allclose(np.asarray(reloaded(x)), np.asarray(y),
                                   rtol=1e-5, atol=1e-5)
        print("  reloaded design lowered and executed ✓ "
              "(no recompile, no closures — specs re-derive the numerics)")


if __name__ == "__main__":
    main()
