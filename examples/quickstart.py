"""Quickstart: compile the paper's motivating example (Fig. 2) with CODO.

    PYTHONPATH=src python examples/quickstart.py

Builds the pad→conv→relu task graph, shows the detected dataflow
violations, runs the full codo_opt pipeline (coarse + fine elimination,
reuse buffers, buffer determination, auto-scheduling), verifies the
lowered program against the unoptimized oracle, and prints the report.
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import codo_opt, lower, verify_lowering, violation_report  # noqa: E402
from repro.kernels import register_all  # noqa: E402
from repro.models.dataflow_models import GB, random_inputs  # noqa: E402


def build_motivating(n=1, c=3, h=32, w=32, co=8):
    b = GB("motivating")
    x = b.input("x", (n, c, h, w))
    y = b.conv(x, co, 3, relu=True)   # emits pad -> conv -> relu tasks
    b.mark_output(y)
    return b.g


def main():
    register_all()                     # route fusion groups to Pallas kernels
    g = build_motivating()

    print("== input dataflow graph ==")
    print(g.summary())
    print("\n== violations before compilation ==")
    print(violation_report(g))

    compiled = codo_opt(g)
    print("\n== codo_opt ==")
    print(compiled.report())

    low = lower(compiled, jit=False)
    print("\n== lowering ==")
    print(low.summary())
    for grp in low.groups:
        print(f"  group {grp.gid}: {grp.tasks} -> {grp.kernel}")

    env = random_inputs(g)
    verify_lowering(g, compiled, env)
    print("\nnumerics verified against the unoptimized oracle ✓")


if __name__ == "__main__":
    main()
