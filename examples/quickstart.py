"""Quickstart: compile the paper's motivating example (Fig. 2) with CODO.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --cache-dir /tmp/codo_cache

Builds the pad→conv→relu task graph with *declarative* op semantics (each
task carries an ``OpSpec`` the registry materializes into jnp on demand),
shows the detected dataflow violations, runs the full codo_opt pipeline
(coarse + fine elimination, reuse buffers, buffer determination,
auto-scheduling), verifies the lowered program against the unoptimized
oracle, and prints the report.

With ``--cache-dir`` it also demonstrates the cold-restart property the
op registry provides: the compile is written to an on-disk cache, reloaded
through a *fresh* cache instance (the in-process analogue of a new
interpreter — run the script twice to see a true cold restart), and the
reloaded design is lowered and executed without recompiling.

With ``--artifact PATH`` it exports the compiled design as a versioned
JSON artifact (docs/artifact_format.md), re-imports it, and verifies the
imported design end to end — the same flow as the compiler CLI's
``--export`` / ``--import-artifact`` verbs and ``repro.launch.serve
--artifact``.
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (CompileCache, artifact_summary, codo_opt,  # noqa: E402
                        export_artifact, import_artifact, lower,
                        verify_lowering, violation_report)
from repro.kernels import register_all  # noqa: E402
from repro.models.dataflow_models import GB, random_inputs  # noqa: E402


def build_motivating(n=1, c=3, h=32, w=32, co=8):
    b = GB("motivating")
    x = b.input("x", (n, c, h, w))
    y = b.conv(x, co, 3, relu=True)   # emits pad -> conv -> relu tasks
    b.mark_output(y)
    return b.g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", default="",
                    help="disk compile-cache dir: demonstrates that a "
                         "reloaded (cold-restart) compile still executes")
    ap.add_argument("--artifact", default="",
                    help="also export/import the design as a versioned "
                         "JSON artifact at this path")
    args = ap.parse_args()

    register_all()                     # route fusion groups to Pallas kernels
    g = build_motivating()

    print("== input dataflow graph ==")
    print(g.summary())
    print("   task specs:", {t.name: t.spec.kind for t in g.tasks})
    print("\n== violations before compilation ==")
    print(violation_report(g))

    compiled = codo_opt(g)
    print("\n== codo_opt ==")
    print(compiled.report())

    low = lower(compiled, jit=False)
    print("\n== lowering ==")
    print(low.summary())
    for grp in low.groups:
        print(f"  group {grp.gid}: {grp.tasks} -> {grp.kernel}")

    env = random_inputs(g)
    verify_lowering(g, compiled, env)
    print("\nnumerics verified against the unoptimized oracle ✓")

    if args.artifact:
        print(f"\n== portable artifact (JSON at {args.artifact}) ==")
        export_artifact(compiled, args.artifact)
        print(artifact_summary(args.artifact))
        imported = import_artifact(args.artifact)
        assert (imported.graph.structural_hash()
                == compiled.graph.structural_hash())
        verify_lowering(build_motivating(), imported, env)
        print("  imported design lowered, executed, and verified ✓")
        print("  CLI equivalents:")
        print("    python -m repro.core.compiler --import-artifact "
              f"{args.artifact}")
        print(f"    python -m repro.launch.serve --artifact {args.artifact}")

    if args.cache_dir:
        print(f"\n== cold-restart demo (disk cache at {args.cache_dir}) ==")
        codo_opt(build_motivating(), cache=CompileCache(disk_dir=args.cache_dir))
        fresh = CompileCache(disk_dir=args.cache_dir)   # knows nothing in memory
        reloaded = codo_opt(build_motivating(), cache=fresh)
        print(f"  reload: cache_hit={reloaded.cache_hit} "
              f"(disk hits: {fresh.stats.disk_hits})")
        assert all(t.fn is not None for t in reloaded.graph.tasks), \
            "disk entry came back stripped"
        verify_lowering(build_motivating(), reloaded, env)
        print("  reloaded design lowered, executed, and verified ✓ "
              "(no recompile, no closures — specs re-derive the numerics)")


if __name__ == "__main__":
    main()
