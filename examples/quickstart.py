"""Quickstart: compile the paper's motivating example (Fig. 2) with CODO.

    PYTHONPATH=src python examples/quickstart.py
    PYTHONPATH=src python examples/quickstart.py --cache-dir /tmp/codo_cache

Builds the pad→conv→relu task graph with *declarative* op semantics (each
task carries an ``OpSpec`` the registry materializes into jnp on demand),
shows the detected dataflow violations, runs the full codo_opt pipeline
(coarse + fine elimination, reuse buffers, buffer determination,
auto-scheduling), verifies the lowered program against the unoptimized
oracle, and prints the report.

With ``--cache-dir`` it also demonstrates the cold-restart property the
op registry provides: the compile is written to an on-disk cache, reloaded
through a *fresh* cache instance (the in-process analogue of a new
interpreter — run the script twice to see a true cold restart), and the
reloaded design is lowered and executed without recompiling.
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (CompileCache, codo_opt, lower, verify_lowering,  # noqa: E402
                        violation_report)
from repro.kernels import register_all  # noqa: E402
from repro.models.dataflow_models import GB, random_inputs  # noqa: E402


def build_motivating(n=1, c=3, h=32, w=32, co=8):
    b = GB("motivating")
    x = b.input("x", (n, c, h, w))
    y = b.conv(x, co, 3, relu=True)   # emits pad -> conv -> relu tasks
    b.mark_output(y)
    return b.g


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", default="",
                    help="disk compile-cache dir: demonstrates that a "
                         "reloaded (cold-restart) compile still executes")
    args = ap.parse_args()

    register_all()                     # route fusion groups to Pallas kernels
    g = build_motivating()

    print("== input dataflow graph ==")
    print(g.summary())
    print("   task specs:", {t.name: t.spec.kind for t in g.tasks})
    print("\n== violations before compilation ==")
    print(violation_report(g))

    compiled = codo_opt(g)
    print("\n== codo_opt ==")
    print(compiled.report())

    low = lower(compiled, jit=False)
    print("\n== lowering ==")
    print(low.summary())
    for grp in low.groups:
        print(f"  group {grp.gid}: {grp.tasks} -> {grp.kernel}")

    env = random_inputs(g)
    verify_lowering(g, compiled, env)
    print("\nnumerics verified against the unoptimized oracle ✓")

    if args.cache_dir:
        print(f"\n== cold-restart demo (disk cache at {args.cache_dir}) ==")
        codo_opt(build_motivating(), cache=CompileCache(disk_dir=args.cache_dir))
        fresh = CompileCache(disk_dir=args.cache_dir)   # knows nothing in memory
        reloaded = codo_opt(build_motivating(), cache=fresh)
        print(f"  reload: cache_hit={reloaded.cache_hit} "
              f"(disk hits: {fresh.stats.disk_hits})")
        assert all(t.fn is not None for t in reloaded.graph.tasks), \
            "disk entry came back stripped"
        verify_lowering(build_motivating(), reloaded, env)
        print("  reloaded design lowered, executed, and verified ✓ "
              "(no recompile, no closures — specs re-derive the numerics)")


if __name__ == "__main__":
    main()
