"""Compile ResNet-18 (Table III workload) end to end, including the
Opt1..Opt5 ablation of Table VII, per-pass diagnostics from the pass
manager, the compile cache (memory tier + cold-restart disk reload), and
the resource/performance sweep of Fig. 11.

    PYTHONPATH=src python examples/compile_resnet18.py
    PYTHONPATH=src python examples/compile_resnet18.py --cache-dir /tmp/codo_cache
    PYTHONPATH=src python examples/compile_resnet18.py --artifact /tmp/resnet18.json

ResNet-18 is built from declarative op specs (``repro.core.ops``), so with
``--cache-dir`` the script proves the portable-artifact property: a fresh
cache instance reloads the compile from disk and the design still lowers
and executes (run the script twice for a true cold interpreter restart —
the second run's "cold" compile is itself a disk hit).
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (ABLATION_PRESETS, CodoOptions, CompileCache,  # noqa: E402
                        artifact_summary, codo_opt, export_artifact,
                        import_artifact, lower)
from repro.models.dataflow_models import random_inputs, resnet18  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", default="",
                    help="disk compile-cache dir for the cold-restart demo")
    ap.add_argument("--artifact", default="",
                    help="export/import the opt5 design as a versioned JSON "
                         "artifact at this path (docs/artifact_format.md)")
    args = ap.parse_args()

    g = resnet18(32)
    print(f"resnet18(3x32x32): {len(g.tasks)} tasks, "
          f"{len(g.buffers)} buffers")

    print("\n== ablation (Table VII / Fig. 10, presets are data) ==")
    for name in ABLATION_PRESETS:
        c = codo_opt(g, CodoOptions.preset(name))
        print(f"  {name} {'+'.join(ABLATION_PRESETS[name]):<42s}"
              f" speedup {c.speedup:9.1f}x  fifo {c.fifo_fraction:4.0%}"
              f"  compile {c.compile_seconds*1e3:6.1f} ms")

    print("\n== per-pass diagnostics (opt5) ==")
    c = codo_opt(g, CodoOptions.opt5(), cache=None)
    print(c.diagnostics.table())

    print("\n== compile cache (memory tier) ==")
    cache = CompileCache()
    cold = codo_opt(resnet18(32), cache=cache)
    warm = codo_opt(resnet18(32), cache=cache)   # fresh build, same structure
    print(f"  cold {cold.compile_seconds*1e3:8.1f} ms")
    print(f"  warm {warm.compile_seconds*1e3:8.1f} ms "
          f"(hit={warm.cache_hit}, same speedup={warm.speedup == cold.speedup})")

    if args.cache_dir:
        print(f"\n== cold-restart reload (disk tier at {args.cache_dir}) ==")
        codo_opt(resnet18(32), cache=CompileCache(disk_dir=args.cache_dir))
        fresh = CompileCache(disk_dir=args.cache_dir)
        reloaded = codo_opt(resnet18(32), cache=fresh)
        print(f"  reload: hit={reloaded.cache_hit} "
              f"disk_hits={fresh.stats.disk_hits} "
              f"compile {reloaded.compile_seconds*1e3:.1f} ms")
        assert all(t.fn is not None for t in reloaded.graph.tasks)
        low = lower(reloaded, jit=False)
        out = low(random_inputs(resnet18(32)))
        print(f"  reloaded design executed: outputs {sorted(out)} ✓")

    if args.artifact:
        print(f"\n== portable artifact ({args.artifact}) ==")
        export_artifact(c, args.artifact)
        print(artifact_summary(args.artifact))
        imported = import_artifact(args.artifact)
        low = lower(imported, jit=False)
        out = low(random_inputs(resnet18(32)))
        print(f"  imported design executed: outputs {sorted(out)} ✓")
        print("  CLI equivalents:")
        print("    python -m repro.core.compiler --configs resnet18 "
              "--opts opt5 --export artifacts/")
        print(f"    python -m repro.core.compiler --import-artifact {args.artifact}")
        print(f"    python -m repro.launch.serve --artifact {args.artifact}")

    print("\n== resource/performance trade-off (Fig. 11) ==")
    for budget in (128, 256, 512, 1024, 2048):
        c = codo_opt(g, CodoOptions(budget_units=budget))
        print(f"  budget {budget:5d}: speedup {c.speedup:9.1f}x  "
              f"units {c.schedule_report.units_used:5d}")


if __name__ == "__main__":
    main()
