"""Compile ResNet-18 (Table III workload) end to end, including the
Opt1..Opt5 ablation of Table VII and the resource/performance sweep of
Fig. 11.

    PYTHONPATH=src python examples/compile_resnet18.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import CodoOptions, codo_opt  # noqa: E402
from repro.models.dataflow_models import resnet18  # noqa: E402


def main():
    g = resnet18(32)
    print(f"resnet18(3x32x32): {len(g.tasks)} tasks, "
          f"{len(g.buffers)} buffers")

    print("\n== ablation (Table VII / Fig. 10) ==")
    for name, opt in [("opt1", CodoOptions.opt1()), ("opt2", CodoOptions.opt2()),
                      ("opt3", CodoOptions.opt3()), ("opt4", CodoOptions.opt4()),
                      ("opt5", CodoOptions.opt5())]:
        c = codo_opt(g, opt)
        print(f"  {name}: speedup {c.speedup:9.1f}x  fifo {c.fifo_fraction:4.0%}"
              f"  compile {c.compile_seconds*1e3:6.1f} ms")

    print("\n== resource/performance trade-off (Fig. 11) ==")
    for budget in (128, 256, 512, 1024, 2048):
        c = codo_opt(g, CodoOptions(budget_units=budget))
        print(f"  budget {budget:5d}: speedup {c.speedup:9.1f}x  "
              f"units {c.schedule_report.units_used:5d}")


if __name__ == "__main__":
    main()
