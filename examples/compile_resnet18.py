"""Compile ResNet-18 (Table III workload) end to end through the
``codo.compile`` frontend, including the Opt1..Opt5 ablation of Table VII,
per-pass diagnostics from the pass manager, the compile cache (memory tier
+ cold-restart disk reload), and the resource/performance sweep of Fig. 11.

    PYTHONPATH=src python examples/compile_resnet18.py
    PYTHONPATH=src python examples/compile_resnet18.py --cache-dir /tmp/codo_cache
    PYTHONPATH=src python examples/compile_resnet18.py --artifact /tmp/resnet18.json

ResNet-18 is a *traced function* (``resnet18_fn`` in
repro/models/dataflow_models.py — plain Python over ShapedBuffers), so the
whole flow is: function -> trace -> six passes -> executable design.
Declarative op specs make every compiled design a portable artifact: with
``--cache-dir`` a fresh cache instance reloads the compile from disk and
the design still lowers and executes (run the script twice for a true cold
interpreter restart — the second run's "cold" compile is itself a disk
hit).
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import codo  # noqa: E402
from repro.core import ABLATION_PRESETS, CompileCache  # noqa: E402
from repro.models.dataflow_models import random_inputs, resnet18_fn  # noqa: E402

SHAPE = (1, 3, 32, 32)


def compile_resnet(**kwargs):
    return codo.compile(resnet18_fn, SHAPE, name="resnet18_32", **kwargs)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cache-dir", default="",
                    help="disk compile-cache dir for the cold-restart demo")
    ap.add_argument("--artifact", default="",
                    help="export/import the opt5 design as a versioned JSON "
                         "artifact at this path (docs/artifact_format.md)")
    args = ap.parse_args()

    program = compile_resnet()
    g = program.source
    print(f"resnet18(3x32x32): traced {len(g.tasks)} tasks, "
          f"{len(g.buffers)} buffers from one Python function")

    print("\n== ablation (Table VII / Fig. 10, presets are data) ==")
    for name in ABLATION_PRESETS:
        c = compile_resnet(options=codo.CodoOptions.preset(name))
        print(f"  {name} {'+'.join(ABLATION_PRESETS[name]):<42s}"
              f" speedup {c.speedup:9.1f}x  fifo {c.fifo_fraction:4.0%}"
              f"  compile {c.compile_seconds*1e3:6.1f} ms")

    print("\n== per-pass diagnostics (opt5) ==")
    c = compile_resnet(options=codo.CodoOptions.opt5(), cache=None)
    print(c.diagnostics.table())

    print("\n== compile cache (memory tier) ==")
    cache = CompileCache()
    cold = compile_resnet(cache=cache)
    warm = compile_resnet(cache=cache)   # fresh trace, same structure
    print(f"  cold {cold.compile_seconds*1e3:8.1f} ms")
    print(f"  warm {warm.compile_seconds*1e3:8.1f} ms "
          f"(hit={warm.cache_hit}, same speedup={warm.speedup == cold.speedup})")

    if args.cache_dir:
        print(f"\n== cold-restart reload (disk tier at {args.cache_dir}) ==")
        compile_resnet(cache=CompileCache(disk_dir=args.cache_dir))
        fresh = CompileCache(disk_dir=args.cache_dir)
        reloaded = compile_resnet(cache=fresh)
        print(f"  reload: hit={reloaded.cache_hit} "
              f"disk_hits={fresh.stats.disk_hits} "
              f"compile {reloaded.compile_seconds*1e3:.1f} ms")
        assert all(t.fn is not None for t in reloaded.graph.tasks)
        out = reloaded.lower(jit=False)(reloaded.make_env(
            **random_inputs(reloaded.graph)))
        print(f"  reloaded design executed: outputs {sorted(out)} ✓")

    if args.artifact:
        print(f"\n== portable artifact ({args.artifact}) ==")
        c.export(args.artifact)
        imported = codo.load(args.artifact)
        out = imported.lower(jit=False)(imported.make_env(
            **random_inputs(imported.graph)))
        print(f"  imported design executed: outputs {sorted(out)} ✓")
        print("  CLI equivalents:")
        print("    python -m repro.core.compiler --configs resnet18 "
              "--opts opt5 --export artifacts/")
        print(f"    python -m repro.core.compiler --import-artifact {args.artifact}")
        print(f"    python -m repro.launch.serve --artifact {args.artifact}")

    print("\n== resource/performance trade-off (Fig. 11) ==")
    for budget in (128, 256, 512, 1024, 2048):
        c = compile_resnet(options=codo.CodoOptions(budget_units=budget))
        print(f"  budget {budget:5d}: speedup {c.speedup:9.1f}x  "
              f"units {c.schedule_report.units_used:5d}")


if __name__ == "__main__":
    main()
