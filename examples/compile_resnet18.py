"""Compile ResNet-18 (Table III workload) end to end, including the
Opt1..Opt5 ablation of Table VII, per-pass diagnostics from the pass
manager, the compile cache, and the resource/performance sweep of Fig. 11.

    PYTHONPATH=src python examples/compile_resnet18.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.core import (ABLATION_PRESETS, CodoOptions, CompileCache,  # noqa: E402
                        codo_opt)
from repro.models.dataflow_models import resnet18  # noqa: E402


def main():
    g = resnet18(32)
    print(f"resnet18(3x32x32): {len(g.tasks)} tasks, "
          f"{len(g.buffers)} buffers")

    print("\n== ablation (Table VII / Fig. 10, presets are data) ==")
    for name in ABLATION_PRESETS:
        c = codo_opt(g, CodoOptions.preset(name))
        print(f"  {name} {'+'.join(ABLATION_PRESETS[name]):<42s}"
              f" speedup {c.speedup:9.1f}x  fifo {c.fifo_fraction:4.0%}"
              f"  compile {c.compile_seconds*1e3:6.1f} ms")

    print("\n== per-pass diagnostics (opt5) ==")
    c = codo_opt(g, CodoOptions.opt5(), cache=None)
    print(c.diagnostics.table())

    print("\n== compile cache ==")
    cache = CompileCache()
    cold = codo_opt(resnet18(32), cache=cache)
    warm = codo_opt(resnet18(32), cache=cache)   # fresh build, same structure
    print(f"  cold {cold.compile_seconds*1e3:8.1f} ms")
    print(f"  warm {warm.compile_seconds*1e3:8.1f} ms "
          f"(hit={warm.cache_hit}, same speedup={warm.speedup == cold.speedup})")

    print("\n== resource/performance trade-off (Fig. 11) ==")
    for budget in (128, 256, 512, 1024, 2048):
        c = codo_opt(g, CodoOptions(budget_units=budget))
        print(f"  budget {budget:5d}: speedup {c.speedup:9.1f}x  "
              f"units {c.schedule_report.units_used:5d}")


if __name__ == "__main__":
    main()
