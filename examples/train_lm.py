"""End-to-end training driver: train a reduced GPT-2 for a few hundred
steps with checkpointing, failure injection, and restart.

    PYTHONPATH=src python examples/train_lm.py --steps 200
    PYTHONPATH=src python examples/train_lm.py --steps 60 --fail-at 30

On the failure run, re-invoke with --resume to continue from the last
checkpoint (bit-exact with the uninterrupted run: deterministic data).
"""

import argparse
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.train import main as train_main  # noqa: E402

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--fail-at", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    argv = ["--arch", "gpt2-medium", "--smoke", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128", "--ckpt", "/tmp/repro_ckpt",
            "--ckpt-every", "20"]
    if args.fail_at:
        argv += ["--fail-at", str(args.fail_at)]
    if args.resume:
        argv += ["--resume"]
    raise SystemExit(train_main(argv))
