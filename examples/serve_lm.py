"""Serve a reduced GPT-2 with slot-based batched decoding.

    PYTHONPATH=src python examples/serve_lm.py
"""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main  # noqa: E402

if __name__ == "__main__":
    raise SystemExit(serve_main(["--arch", "gpt2-medium", "--smoke",
                                 "--requests", "8", "--batch", "4",
                                 "--max-new", "12", "--cache-len", "64"]))
