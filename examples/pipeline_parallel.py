"""Pipeline-parallel execution demo: CODO's balanced stages on a device
mesh (Fig. 1 at pod scale) — runs on 8 virtual CPU devices.

    PYTHONPATH=src python examples/pipeline_parallel.py

The CODO scheduler assigns tasks to latency-balanced stages
(core.schedule.assign_stages); the pipeline executor streams microbatches
through the stage ring over collective_permute — the inter-stage FIFO.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core.pipeline import (PipelineSchedule, pipeline_fn,  # noqa: E402
                                 reference_serial)
from repro.core import codo_opt, assign_stages  # noqa: E402
from repro.launch.mesh import make_debug_mesh  # noqa: E402
from repro.models.dataflow_models import autoencoder  # noqa: E402


def main():
    # 1) CODO stage balancing on a real task graph
    g = autoencoder(64, 784)
    compiled = codo_opt(g)
    stages = assign_stages(compiled.graph, compiled.options.hw, num_stages=4)
    print("CODO-balanced stages:")
    for i, names in enumerate(stages):
        print(f"  stage {i}: {names}")

    # 2) pipeline execution of a 4-stage MLP over 8 microbatches
    mesh = make_debug_mesh((4,), ("stage",))
    D, nmb, mb = 32, 8, 4

    def stage(p, x):
        return jnp.tanh(x @ p["w"] + p["b"])

    key = jax.random.PRNGKey(0)
    params = {"w": jax.random.normal(key, (4, D, D)) * 0.5,
              "b": jnp.zeros((4, D))}
    x = jax.random.normal(jax.random.PRNGKey(1), (nmb, mb, D))

    fn = pipeline_fn([stage] * 4, mesh)
    y = fn(params, x)
    y_ref = reference_serial([stage] * 4, params, x)
    err = float(jnp.abs(y - y_ref).max())
    sched = PipelineSchedule(num_stages=4, num_microbatches=nmb)
    print(f"\npipeline vs serial max err: {err:.2e}")
    print(f"ticks={sched.ticks} bubble={sched.bubble_fraction:.1%} "
          f"(GPipe fill/drain)")
    assert err < 1e-5


if __name__ == "__main__":
    main()
